"""The ``population`` backend: trace-then-solve cross-device execution.

Registered with the §8 registry like any other backend; ``run(arm)`` does
the two-phase dance:

  1. **trace** (``population.trace.run_trace``) — discrete-event timestamp
     arithmetic over the node/topology traces, no model compute, emitting
     the content-addressed compute graph and per-round plans;
  2. **solve** (``population.solve.solve``) — execute the non-lost rounds
     through the arm's fused cohort round-step, one dispatch per round.

Capability record: ``supports_secagg=False`` because no SecAgg wire
protocol runs — SecAgg *cost* is still modeled at the aggregate level when
the arm declares ``secure_uploads`` (setup/recovery bytes, recovery
latency), but no ciphertext ever exists, so configs requesting
``use_secagg=True`` are refused at validation instead of silently running
plaintext.  ``supports_subsampling=True`` makes this the one backend where
``participation_rate < 1`` is allowed: the trace's ``CohortSampler`` uses
the exact ``q`` the arm's accountant composes at.  ``bit_exact_group`` is
empty — the backend is fused-only, so the registry-wide "every group
member runs every arm" promise cannot hold; the q=1 bit-identity with the
``ideal`` backend is pinned by an explicit test instead
(``tests/test_population.py``).
"""

from __future__ import annotations

from typing import Sequence

import repro.obs as obs
from repro.arms.backends import BackendInfo, RunSetup, register_backend
from repro.arms.base import Arm, RoundArm, tree_bytes
from repro.arms.results import RunReport, SimTiming
from repro.arms.runners import default_topology
from repro.population.solve import SolveReport, solve
from repro.population.trace import Trace, run_trace
from repro.sim.nodes import HospitalNode
from repro.sim.topology import Topology

# Trace-default hardware when the caller pins no nodes: every hospital a
# mid-range box, always online (the idealized-conditions population).
_DEFAULT_THROUGHPUT = 400.0
_DEFAULT_OVERHEAD = 0.02


@register_backend(BackendInfo(
    name="population",
    supports_fused=True,
    supports_secagg=False,
    supports_sim_time=True,
    fused_only=True,
    supports_subsampling=True,
    bit_exact_group="",
    description="trace-then-solve cross-device engine: event-free trace "
                "phase over 1000-hospital populations, fused batched solve",
))
class PopulationRunner:
    """Trace-then-solve execution of fused-capable round arms."""

    def __init__(self, nodes: Sequence[HospitalNode] | None = None,
                 topo: Topology | None = None, on_round=None) -> None:
        self.nodes = list(nodes) if nodes is not None else None
        self.topo = topo
        self.on_round = on_round
        self.last_trace: Trace | None = None
        self.last_solve: SolveReport | None = None

    @classmethod
    def from_setup(cls, setup: RunSetup) -> "PopulationRunner":
        return cls(setup.nodes, setup.topo, on_round=setup.on_round)

    def trace(self, arm: Arm) -> Trace:
        """The trace phase alone — no model compute, fresh every call.

        Consumes no arm state (``round_cost``/``quorum``/``facilitator``
        are pure), so tracing twice with fresh topologies is the
        determinism check the CLI exposes.
        """
        if not isinstance(arm, RoundArm) or not arm.fused_capable:
            raise TypeError(
                f"backend 'population' only executes fused-capable round "
                f"arms; got {arm.name!r} (mode={arm.mode!r})"
            )
        cfg = arm.cfg
        nodes = self.nodes
        if nodes is None:
            nodes = [
                HospitalNode(i, _DEFAULT_THROUGHPUT, _DEFAULT_OVERHEAD)
                for i in range(arm.h)
            ]
        if len(nodes) != arm.h:
            raise ValueError(
                f"one HospitalNode per participant required "
                f"({len(nodes)} nodes, {arm.h} participants)"
            )
        topo = self.topo or default_topology(arm.topology_kind, arm.h,
                                             cfg.fl_server)
        topo.advance_to(0.0)
        model_bytes = tree_bytes(arm.init_params(), cfg.bytes_per_param)
        minimum, require = arm.quorum()
        # secure=True models the aggregate-level SecAgg cost whenever the
        # arm's protocol runs behind SecAgg in production, even though this
        # backend never executes the wire protocol (use_secagg is refused)
        with obs.span("population.trace", cat="population",
                      hospitals=arm.h, rounds=arm.planned_rounds()):
            return run_trace(
                nodes, topo,
                rounds=arm.planned_rounds(),
                q=cfg.participation_rate,
                seed=cfg.seed,
                sizes=[arm.round_cost(i) for i in range(arm.h)],
                model_bytes=model_bytes,
                secure=arm.secure_uploads,
                quorum=minimum,
                require=require,
                facilitator=arm.facilitator,
                secagg_threshold=cfg.secagg_threshold,
                eval_every=cfg.eval_every,
            )

    def run(self, arm: Arm) -> RunReport:
        trace = self.trace(arm)
        with obs.span("population.solve", cat="population",
                      hospitals=arm.h, rounds=len(trace.rounds)):
            result = solve(trace, arm, on_round=self.on_round)
        self.last_trace = trace
        self.last_solve = result.report
        rep = result.report
        return RunReport(
            params=result.params, logs=result.logs, epsilon=result.epsilon,
            rounds_completed=rep.rounds_completed, arm=arm.name,
            backend=self.backend,
            timing=SimTiming(
                wall_clock=rep.simulated_seconds,
                bytes_on_wire=rep.bytes_on_wire,
                dropout_events=rep.dropout_events,
                recoveries=rep.recoveries,
                lost_rounds=rep.lost_rounds,
                events=trace.events,
                noise_topups=rep.noise_topups,
            ),
        )
