"""``python -m repro.population`` — cross-device scaling runs + artifacts.

    python -m repro.population --hospitals 50,200,1000 --seeds 0,1,2
    python -m repro.population --hospitals 200 --rounds 4 \
        --participation 0.25 --check-determinism --out BENCH_population.json

Each (arm, H, seed) cell runs the trace-then-solve engine *directly*
(``PopulationRunner``, not the scenario cache), because this CLI reports
what the scenario metrics dict flattens away: the solve report's two
clocks (simulated vs host seconds), the compute-graph size and content
hash, and the realised cohort statistics.  ``--check-determinism``
re-traces every cell and fails the run unless the compute graph is
byte-identical — the DESIGN.md §10 contract, exercised by the CI
``population-smoke`` job on every push.

The artifact (``BENCH_population.json``) carries per-cell records, the
seed-collapsed groups with confidence intervals, and power-law fits
(wall vs H, bytes vs H) over the group means — the same report helpers
the sweep artifacts use, so the numbers are directly comparable.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def _ints(s: str) -> list[int]:
    return [int(x) for x in s.split(",") if x]


def _cell_spec(args, arm: str, hospitals: int, seed: int):
    from repro.scenarios.spec import ScenarioSpec

    population = {
        "topology": args.topology,
        "degree": args.degree,
        "flaky_fraction": args.flaky_fraction,
        "throughput_sigma": args.throughput_sigma,
    }
    return ScenarioSpec(
        name=f"population/arm={arm},hospitals={hospitals},seed={seed}",
        task="gemini", model_size="small", features=args.features,
        examples=args.examples, rounds=args.rounds,
        batch_size=args.batch, lr=0.4, seed=seed,
        arm=arm, backend="population", hospitals=hospitals,
        noise_multiplier=args.sigma, use_secagg=False,
        participation_rate=args.participation,
        population=population,
    )


def _run_cell(spec, check_determinism: bool) -> dict:
    import repro.arms as arms_lib
    from repro.arms import backends as backends_lib
    from repro.population.backend import PopulationRunner
    from repro.scenarios import presets as presets_lib
    from repro.scenarios.executor import build_scenario

    model, silos, cfg, nodes, topo = build_scenario(spec)
    arm_cls = arms_lib.get(spec.arm)
    backends_lib.validate_run(arm_cls, PopulationRunner.info, cfg)
    arm = arm_cls(model, silos, cfg)
    runner = PopulationRunner(nodes, topo)
    t0 = time.time()
    rep = runner.run(arm)
    host_seconds = time.time() - t0
    sr = runner.last_solve

    import jax
    import numpy as np

    n_params = int(sum(
        int(np.prod(np.shape(leaf)) or 1)
        for leaf in jax.tree_util.tree_leaves(rep.params)
    ))
    record = {
        "name": spec.name,
        "task": spec.task,
        "arm": spec.arm,
        "backend": spec.backend,
        "hospitals": spec.hospitals,
        "seed": spec.seed,
        "model_size": spec.model_size,
        "model_params": n_params,
        "participation_rate": spec.participation_rate,
        "rounds_completed": rep.rounds_completed,
        "epsilon": float(rep.epsilon),
        "accuracy": presets_lib.pooled_metric(spec, model, rep.params, silos),
        "wall_clock": float(rep.wall_clock),        # simulated seconds
        "bytes_on_wire": float(rep.bytes_on_wire),
        "recoveries": int(rep.recoveries),
        "lost_rounds": int(rep.lost_rounds),
        "dropout_events": int(rep.dropout_events),
        "noise_topups": int(rep.noise_topups),
        "host_seconds": host_seconds,
        # solve-report extras the scenario metrics dict flattens away
        "solve_wall_seconds": sr.wall_seconds,
        "graph_nodes": sr.graph_nodes,
        "graph_hash": sr.graph_hash,
        "empirical_q": sr.empirical_q,
        "mean_cohort": sr.mean_cohort,
    }
    if check_determinism:
        # fresh nodes/topo (run_trace advances topologies); same arm — the
        # trace phase consumes no arm state
        _, _, _, nodes2, topo2 = build_scenario(spec)
        retraced = PopulationRunner(nodes2, topo2).trace(arm)
        if retraced.graph.to_json_bytes() != \
                runner.last_trace.graph.to_json_bytes():
            raise AssertionError(
                f"{spec.name}: re-trace produced a different compute graph "
                f"({retraced.graph.graph_hash()} vs {sr.graph_hash}) — "
                f"the trace phase is not deterministic"
            )
        record["determinism_checked"] = True
    return record


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.population",
        description="Trace-then-solve cross-device scaling runs.",
    )
    p.add_argument("--hospitals", type=_ints, default=[50, 200, 1000],
                   help="comma-separated cohort sizes (default 50,200,1000)")
    p.add_argument("--seeds", type=_ints, default=[0, 1, 2],
                   help="comma-separated seeds, one run per seed per cell")
    p.add_argument("--arms", default="decaph,fl",
                   help="comma-separated fused-capable round arms")
    p.add_argument("--rounds", type=int, default=5)
    p.add_argument("--participation", type=float, default=0.1,
                   help="Poisson cohort subsampling rate q in (0, 1]")
    p.add_argument("--topology", default="k_regular",
                   help="population overlay: k_regular | small_world | "
                        "star | ring | full")
    p.add_argument("--degree", type=int, default=8,
                   help="k for the k_regular/small_world overlays")
    p.add_argument("--flaky-fraction", type=float, default=0.05)
    p.add_argument("--throughput-sigma", type=float, default=0.5)
    p.add_argument("--examples", type=int, default=6000,
                   help="total examples across the cohort")
    p.add_argument("--features", type=int, default=16)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--sigma", type=float, default=0.8,
                   help="DP noise multiplier")
    p.add_argument("--check-determinism", action="store_true",
                   help="re-trace every cell; fail unless the compute graph "
                        "is byte-identical")
    p.add_argument("--out", default="BENCH_population.json")
    args = p.parse_args(argv)

    arms = [a for a in args.arms.split(",") if a]
    records = []
    for arm in arms:
        for h in args.hospitals:
            for seed in args.seeds:
                spec = _cell_spec(args, arm, h, seed)
                t0 = time.time()
                rec = _run_cell(spec, args.check_determinism)
                records.append(rec)
                print(
                    f"{spec.name}: sim {rec['wall_clock']:.1f}s over "
                    f"{rec['rounds_completed']} rounds "
                    f"({rec['graph_nodes']} graph nodes, "
                    f"solve {rec['solve_wall_seconds']:.1f}s, "
                    f"cell {time.time() - t0:.1f}s host)",
                    file=sys.stderr,
                )

    from repro.scenarios import report as report_lib

    payload = {
        "suite": "population",
        "participation_rate": args.participation,
        "topology": args.topology,
        "cells": records,
        "seed_groups": report_lib.aggregate_seeds(records),
        "scaling_laws": report_lib.scaling_laws(records),
        "generated_by": "python -m repro.population",
    }
    out = Path(args.out)
    out.write_text(json.dumps(payload, indent=2, sort_keys=True))
    print(f"wrote {out} ({len(records)} cells)", file=sys.stderr)
    for law, fits in payload["scaling_laws"].items():
        for arm, fit in sorted(fits.items()):
            print(f"  {law} [{arm}]: exponent {fit['exponent']:.3f} "
                  f"(R² {fit['r2']:.3f}, {fit['points']} pts)",
                  file=sys.stderr)
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
