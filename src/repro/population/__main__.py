from repro.population.cli import main

raise SystemExit(main())
