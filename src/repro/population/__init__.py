"""repro.population — trace-then-solve cross-device engine (DESIGN.md §10).

Everything before this subsystem was cross-silo: the discrete-event engine
in ``repro.sim`` interleaves event scheduling with model compute, welding
"one simulated hospital" to "one in-process compute step", which caps H at
a few dozen.  This package decouples them with the coordinator/broker/worker
split of the decentralized-learning-simulator exemplar (SNIPPETS.md §3):

  * **trace** (``repro.population.trace``) — a discrete-event pass with NO
    model compute.  It consumes per-hospital availability/throughput traces,
    a sparse topology (k-regular / small-world at H=1000, link churn) and a
    first-class Poisson **cohort sampler** (``repro.population.sampler``),
    and emits a timestamped, content-addressed **compute graph**
    (``repro.population.graph``): train/aggregate/eval nodes with
    data-dependency edges.  Byte-identical for a fixed seed — the
    determinism contract the solve cache relies on.
  * **solve** (``repro.population.solve``) — topologically schedules that
    graph, executing each round's thousands of per-client train leaves as
    ONE fused cohort dispatch (the §7 round-step), with a ``SolveReport``
    separating simulated time from host wall time.

``repro.population.backend`` registers the pair as the ``population``
backend (fused-only, no per-event SecAgg service: SecAgg cost is modeled at
the aggregate level with the trace's sampled dropouts feeding the existing
recovery-byte math).  ``PopulationSpec`` (``repro.population.spec``)
generates 1000-hospital node/topology traces from distributions, consumable
from ``ScenarioSpec.population``; ``python -m repro.population`` is the CLI.
"""

from __future__ import annotations

from repro.population.graph import ComputeGraph, TraceNode
from repro.population.sampler import CohortSampler
from repro.population.spec import PopulationSpec

__all__ = [
    "CohortSampler",
    "ComputeGraph",
    "PopulationSpec",
    "TraceNode",
]
