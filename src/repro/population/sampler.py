"""The first-class cohort sampler: per-round Poisson subsampling of hospitals.

The paper's DP accountant (``core.accountant``) analyses the Sampled
Gaussian Mechanism — it has assumed Poisson subsampling since the seed —
but the repo never actually *sampled*: every backend ran every hospital
every round.  ``CohortSampler`` closes that gap: each round, every hospital
joins the cohort independently with probability ``q``
(``ArmConfig.participation_rate``), and the same ``q`` is what the arm
hands its accountant (``rate * participation_rate`` — see
``DeCaPHArm``), so ε accounting and simulation agree by construction.

Two-level-sampling caveat (documented, conservative direction): the
accountant treats the composition as example-level Poisson sampling at
rate ``q * rate``.  The real mechanism samples hospitals at ``q`` and then
examples at ``rate`` within each sampled hospital; for any one example the
marginal inclusion probability is exactly ``q * rate``, and the amplified
RDP of the two-level scheme is bounded by the example-level analysis at
that marginal rate for the per-example-clipped sums the arms upload.
Hospitals offline at round start only *shrink* the realised cohort below
``q``'s expectation, which weakens the mechanism's data exposure, never
strengthens it — the accountant stays an upper bound.

Determinism: the round-``t`` draw comes from its own
``random.Random(f"{seed}:{t}")`` stream (string seeds hash via SHA-512,
stable across Python versions), so cohorts are a pure function of
``(seed, t)`` — independent of execution order, resumable mid-run, and
identical between the trace phase and any re-trace (the byte-identical
contract in DESIGN.md §10).
"""

from __future__ import annotations

import random


class CohortSampler:
    """Poisson (independent Bernoulli-``q``) subsampling over ``h`` hospitals."""

    def __init__(self, h: int, q: float, seed: int) -> None:
        if not 0.0 < q <= 1.0:
            raise ValueError(f"participation rate q must be in (0, 1], got {q}")
        if h < 1:
            raise ValueError("need at least one hospital")
        self.h = h
        self.q = q
        self.seed = seed
        # empirical bookkeeping: over many rounds, selected/offered -> q
        self.offered = 0
        self.selected = 0

    def cohort(self, t: int) -> list[int]:
        """Round ``t``'s sampled cohort, ascending hospital index."""
        self.offered += self.h
        if self.q >= 1.0:
            # full participation consumes no randomness: with q=1 the
            # population backend is bit-identical to the idealized backend
            self.selected += self.h
            return list(range(self.h))
        rng = random.Random(f"{self.seed}:{t}")
        out = [i for i in range(self.h) if rng.random() < self.q]
        self.selected += len(out)
        return out

    def empirical_rate(self) -> float:
        """Fraction of (hospital, round) slots actually sampled so far."""
        return self.selected / self.offered if self.offered else 0.0
