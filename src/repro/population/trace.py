"""The trace phase: discrete-event simulation with NO model compute.

``repro.sim``'s engine interleaves event scheduling with in-process JAX
dispatches, so simulating a hospital costs a model step — H=1000 is
unreachable.  The trace phase breaks that weld: it walks the synchronous
round structure (cohort sample → download → local compute → upload →
aggregate) purely as *timestamp arithmetic* over the node/topology traces,
using each hospital's **expected** batch size for compute time (the actual
Poisson draws happen at solve time, inside the arm's own rng stream), and
emits two artifacts:

  * a content-addressed ``ComputeGraph`` (train/aggregate/eval nodes with
    data-dependency edges) — byte-identical for a fixed spec + seed;
  * a compact per-round ``RoundPlan`` list the solver walks (who was
    sampled, who delivered, who dropped mid-round, where time went).

Sparse topologies are first-class: uploads route along min-hop BFS paths
to the facilitator, paying every edge's latency + serialisation and
charging bytes per traversed link (relay cost is real traffic).  SecAgg is
modeled at the aggregate level: when the arm declares ``secure_uploads``
the trace charges the existing setup/recovery byte math
(``core.secagg.secagg_recovery_bytes``) — no per-event ciphertext service
runs (the ``population`` backend is capability-negotiated accordingly).

Stdlib + ``repro.sim`` data types only — importing this module never pays
for JAX.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Sequence

from repro.population.graph import ComputeGraph, round_ts
from repro.population.sampler import CohortSampler
from repro.sim.nodes import HospitalNode
from repro.sim.topology import Topology


@dataclasses.dataclass
class RoundPlan:
    """What the trace decided for one protocol round (solver input)."""

    t: int
    start: float
    end: float
    dst: int
    cohort: tuple[int, ...]          # sampled ∩ online at round start
    delivered: tuple[int, ...]       # uploads that reached dst
    dropped: tuple[int, ...]         # sampled but lost mid-round
    lost: bool                       # round void (quorum/dst/threshold)
    reason: str = ""                 # why it was lost ("" = completed)


@dataclasses.dataclass
class Trace:
    """The trace phase's full output."""

    graph: ComputeGraph
    rounds: list[RoundPlan]
    wall_clock: float                # simulated seconds at trace end
    bytes_on_wire: float
    dropout_events: int
    recoveries: int                  # aggregate-level SecAgg recoveries
    lost_rounds: int
    events: int                      # trace decisions taken (graph+round ops)
    empirical_q: float
    mean_cohort: float


def _online_at(node: HospitalNode, t: float) -> bool:
    for t_off, t_on in node.dropouts:
        if t_off <= t and (t_on is None or t < t_on):
            return False
    return True


def _next_transition(nodes: Sequence[HospitalNode], t: float) -> float | None:
    """Earliest availability boundary strictly after ``t`` (quorum stall)."""
    best: float | None = None
    for node in nodes:
        for t_off, t_on in node.dropouts:
            for b in (t_off, t_on):
                if b is not None and b > t and (best is None or b < best):
                    best = b
    return best


def _drops_within(node: HospitalNode, t0: float, t1: float) -> bool:
    """Does a dropout window open inside (t0, t1]? (mid-round loss)"""
    return any(t0 < t_off <= t1 for t_off, _ in node.dropouts)


def _path_costs(
    topo: Topology, dst: int, nbytes: float
) -> tuple[dict[int, int], dict[int, float]]:
    """BFS from ``dst``: min-hop count and summed per-edge transfer time for
    shipping ``nbytes`` from every reachable node to ``dst``."""
    hops = {dst: 0}
    cost = {dst: 0.0}
    q: deque[int] = deque([dst])
    while q:
        u = q.popleft()
        for v in topo.neighbors(u):
            if v not in hops:
                hops[v] = hops[u] + 1
                # store-and-forward: each hop pays latency + serialisation
                cost[v] = cost[u] + topo.transfer_time(v, u, nbytes)
                q.append(v)
    return hops, cost


def run_trace(
    nodes: Sequence[HospitalNode],
    topo: Topology,
    *,
    rounds: int,
    q: float,
    seed: int,
    sizes: Sequence[int],                 # expected examples per hospital round
    model_bytes: float,
    secure: bool,                          # model SecAgg setup/recovery cost
    quorum: int,
    require: int | None,                   # node that must be online (star hub)
    facilitator: Callable[[int, Sequence[int]], int],
    secagg_threshold: int | None = None,
    eval_every: int = 0,
) -> Trace:
    """Trace ``rounds`` synchronous rounds over the population."""
    h = len(nodes)
    sampler = CohortSampler(h, q, seed)
    graph = ComputeGraph()
    plans: list[RoundPlan] = []
    now = 0.0
    wire = 0.0
    recoveries = 0
    lost_rounds = 0
    events = 0
    prev_agg_id: tuple[str, ...] = ()    # dep edge: params came from here

    def lose(t: int, start: float, end: float, dst: int, cohort, delivered,
             dropped, reason: str) -> None:
        nonlocal lost_rounds
        lost_rounds += 1
        plans.append(RoundPlan(
            t=t, start=round_ts(start), end=round_ts(end), dst=dst,
            cohort=tuple(cohort), delivered=tuple(delivered),
            dropped=tuple(dropped), lost=True, reason=reason,
        ))

    for t in range(rounds):
        topo.advance_to(now)  # fold scheduled link churn into the graph
        sampled = sampler.cohort(t)
        cohort = [i for i in sampled if _online_at(nodes[i], now)]
        events += 1
        hub_down = require is not None and not _online_at(nodes[require], now)
        if len(cohort) < max(quorum, 1) or hub_down:
            # stall to the next availability transition, like the event
            # backend's quorum wait — if none remains, the run is over
            nxt = _next_transition(nodes, now)
            lose(t, now, now, -1, cohort, (), (),
                 "hub offline" if hub_down else "below quorum")
            if nxt is None:
                break
            now = nxt
            continue
        dst = facilitator(t, cohort)
        # uploads and downloads both ship one model copy, so one BFS covers
        # both directions (links are symmetric by construction)
        hops, upcost = _path_costs(topo, dst, model_bytes)
        dlcost = upcost

        delivered: list[int] = []
        dropped: list[int] = []
        train_ids: list[str] = []
        t_last_arrival = now
        for i in cohort:
            if i not in hops:
                dropped.append(i)   # partitioned from the facilitator
                graph.add("train", round=t, hospital=i, t_start=now,
                          t_end=now, size=int(sizes[i]), deps=prev_agg_id,
                          delivered=False)
                events += 1
                continue
            dl = dlcost[i]                       # model download to i
            t_start = now + dl
            t_compute = nodes[i].compute_time(int(sizes[i]))
            t_up = upcost[i]                      # upload back to dst
            t_arrive = t_start + t_compute + t_up
            # bytes ride every traversed link, both directions
            wire += hops[i] * model_bytes * 2
            ok = not _drops_within(nodes[i], now, t_arrive)
            node = graph.add(
                "train", round=t, hospital=i, t_start=t_start,
                t_end=t_start + t_compute, size=int(sizes[i]),
                deps=prev_agg_id, delivered=ok,
            )
            events += 1
            if ok:
                delivered.append(i)
                train_ids.append(node.id)
                t_last_arrival = max(t_last_arrival, t_arrive)
            else:
                dropped.append(i)

        if secure:
            wire += _recovery_bytes(len(cohort))["setup_bytes"]
        dst_dead = dst in dropped or _drops_within(nodes[dst], now,
                                                   t_last_arrival)
        if dst_dead or not delivered:
            lose(t, now, t_last_arrival, dst, cohort, delivered, dropped,
                 "facilitator died" if dst_dead else "nothing delivered")
            now = max(now, t_last_arrival)
            continue
        t_agg = t_last_arrival
        if secure:
            threshold = secagg_threshold or (len(cohort) // 2 + 1)
            if len(delivered) < threshold:
                lose(t, now, t_agg, dst, cohort, delivered, dropped,
                     "below secagg threshold")
                now = t_agg
                continue
            if dropped:
                # survivors reveal the dropped secrets' shares: one extra
                # latency-bound round trip plus the recovery bytes
                recoveries += len(dropped)
                wire += _recovery_bytes(len(cohort),
                                        len(dropped))["recovery_bytes"]
                t_agg += 2 * max(
                    hops[i] * _min_latency(topo, i) for i in delivered
                )
        agg = graph.add(
            "aggregate", round=t, hospital=dst, t_start=t_last_arrival,
            t_end=t_agg, size=len(delivered), deps=tuple(train_ids),
        )
        events += 1
        prev_agg_id = (agg.id,)
        if eval_every and (t + 1) % eval_every == 0:
            ev = graph.add("eval", round=t, hospital=dst, t_start=t_agg,
                           t_end=t_agg, size=len(delivered), deps=(agg.id,))
            events += 1
            del ev
        plans.append(RoundPlan(
            t=t, start=round_ts(now), end=round_ts(t_agg), dst=dst,
            cohort=tuple(cohort), delivered=tuple(delivered),
            dropped=tuple(dropped), lost=False,
        ))
        now = t_agg

    n_dropout_events = sum(
        sum(1 for t_off, _ in node.dropouts if t_off <= now)
        for node in nodes
    )
    completed = [p for p in plans if not p.lost]
    mean_cohort = (sum(len(p.cohort) for p in plans) / len(plans)
                   if plans else 0.0)
    return Trace(
        graph=graph, rounds=plans, wall_clock=round_ts(now),
        bytes_on_wire=wire, dropout_events=n_dropout_events,
        recoveries=recoveries, lost_rounds=lost_rounds, events=events,
        empirical_q=sampler.empirical_rate(), mean_cohort=mean_cohort,
    )


def _min_latency(topo: Topology, i: int) -> float:
    nbrs = topo.neighbors(i)
    if not nbrs:
        return 0.0
    return min(topo.link(i, j).latency for j in nbrs)


def _recovery_bytes(n: int, dropped: int = 0) -> dict:
    from repro.core.secagg import secagg_recovery_bytes

    return secagg_recovery_bytes(n, dropped) if dropped else \
        secagg_recovery_bytes(n)
