"""The timestamped, content-addressed compute graph the trace phase emits.

A ``TraceNode`` is one unit of schedulable work — ``train`` (one sampled
hospital's local round), ``aggregate`` (the facilitator's reduce +
model step), or ``eval`` — with simulated start/end timestamps and
data-dependency edges (``deps``).  Node ids are content hashes of the
node's own record plus its dependencies' ids, so the id of any node pins
the entire causal history beneath it (a Merkle DAG): two traces agree on a
node id iff they agree on everything that node's result could depend on.

``ComputeGraph.to_json_bytes()`` is the canonical serialisation — sorted
keys, fixed separators, no floats beyond their ``repr`` — and the byte
string the determinism contract (DESIGN.md §10, enforced by
``tests/test_population.py`` and the CI ``population-smoke`` job) is
stated over: same spec + seed ⇒ byte-identical graph.  ``graph_hash()``
is the sha256 of those bytes, the solve phase's cache key.

Stdlib-only: the trace phase must never pay the JAX import.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable

from repro.canon import bytes_hash, canonical_json_bytes, content_hash

KINDS = ("train", "aggregate", "eval")


@dataclasses.dataclass(frozen=True)
class TraceNode:
    """One schedulable unit of the traced computation."""

    id: str                      # content hash (assigned by ComputeGraph.add)
    kind: str                    # train | aggregate | eval
    round: int
    hospital: int                # owner (train: the hospital; aggregate/eval:
                                 # the facilitator)
    t_start: float               # simulated seconds
    t_end: float
    size: int                    # train: examples; aggregate: cohort delivered
    deps: tuple[str, ...]        # data-dependency edge ids
    delivered: bool = True       # train only: upload reached the facilitator

    def record(self) -> dict:
        d = dataclasses.asdict(self)
        d["deps"] = list(self.deps)
        return d


def _node_id(record: dict) -> str:
    material = {k: v for k, v in record.items() if k != "id"}
    return content_hash(material)


class ComputeGraph:
    """Append-only DAG of ``TraceNode``s in topological (trace) order."""

    def __init__(self) -> None:
        self.nodes: list[TraceNode] = []
        self._by_id: dict[str, TraceNode] = {}

    def __len__(self) -> int:
        return len(self.nodes)

    def add(
        self,
        kind: str,
        *,
        round: int,
        hospital: int,
        t_start: float,
        t_end: float,
        size: int,
        deps: Iterable[str] = (),
        delivered: bool = True,
    ) -> TraceNode:
        if kind not in KINDS:
            raise ValueError(f"kind {kind!r} not in {KINDS}")
        deps = tuple(deps)
        for d in deps:
            if d not in self._by_id:
                raise ValueError(f"dep {d!r} not in graph (topological order "
                                 "violated)")
        record = {
            "kind": kind, "round": round, "hospital": hospital,
            # repr-stable rounding: timestamps are sums of spec-derived
            # floats, identical across re-traces of the same spec
            "t_start": round_ts(t_start), "t_end": round_ts(t_end),
            "size": size, "deps": list(deps), "delivered": delivered,
        }
        node = TraceNode(
            id=_node_id(record), kind=kind, round=round, hospital=hospital,
            t_start=record["t_start"], t_end=record["t_end"], size=size,
            deps=deps, delivered=delivered,
        )
        self.nodes.append(node)
        self._by_id[node.id] = node
        return node

    def get(self, node_id: str) -> TraceNode:
        return self._by_id[node_id]

    # -- topological scheduling ----------------------------------------------

    def waves(self) -> list[list[TraceNode]]:
        """Kahn topological waves: wave k holds every node whose deps all
        live in waves < k.  The solve phase executes wave by wave; within a
        wave, train leaves batch into one fused dispatch."""
        depth: dict[str, int] = {}
        out: list[list[TraceNode]] = []
        for node in self.nodes:  # append order is already topological
            d = 1 + max((depth[dep] for dep in node.deps), default=-1)
            depth[node.id] = d
            while len(out) <= d:
                out.append([])
            out[d].append(node)
        return out

    # -- canonical serialisation ----------------------------------------------

    def to_json_bytes(self) -> bytes:
        """THE canonical byte encoding (determinism contract target)."""
        payload = {"schema": 1, "nodes": [n.record() for n in self.nodes]}
        return canonical_json_bytes(payload)

    def graph_hash(self) -> str:
        return bytes_hash(self.to_json_bytes(), chars=20)

    @classmethod
    def from_json_bytes(cls, raw: bytes) -> "ComputeGraph":
        payload = json.loads(raw.decode())
        g = cls()
        for rec in payload["nodes"]:
            node = TraceNode(
                id=rec["id"], kind=rec["kind"], round=rec["round"],
                hospital=rec["hospital"], t_start=rec["t_start"],
                t_end=rec["t_end"], size=rec["size"],
                deps=tuple(rec["deps"]), delivered=rec["delivered"],
            )
            if _node_id(node.record()) != node.id:
                raise ValueError(f"corrupt graph: node {node.id} fails its "
                                 "content hash")
            g.nodes.append(node)
            g._by_id[node.id] = node
        return g


def round_ts(t: float) -> float:
    """Timestamp canonicalisation: microsecond grid, repr-stable."""
    return round(float(t), 6)
