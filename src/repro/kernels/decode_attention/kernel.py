"""Pallas TPU kernel: single-query attention against a long KV cache.

The decode hot-spot for the 32k/500k serving shapes: one query row per
(batch, head) streamed against KV blocks with online-softmax running stats in
VMEM.  The KV length is the innermost grid dimension so the cache streams
HBM->VMEM exactly once; positions beyond ``index`` (and outside the sliding
window) are masked with the current-position scalar prefetched via
PrefetchScalarGridSpec.

GQA is expressed in the index map (KV head = h // group) — the cache is
never expanded.  Block = (bk, d): bk = 512, d = 128 -> 0.5 MiB fp32 per K/V
step, well under VMEM, and the dominant HBM term is the unavoidable one
(reading the cache once).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(index_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, block_k: int, window,
                   kv_steps: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    index = index_ref[0]
    q = q_ref[0, 0].astype(jnp.float32) * scale          # [1, d]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [1, bk]
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    ok = k_pos <= index
    if window is not None:
        ok &= k_pos > index - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(kj == kv_steps - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, index: jax.Array, *,
    window: int | None = None, block_k: int = 512, interpret: bool = False,
) -> jax.Array:
    """q: [B,1,H,D]; k,v: [B,L,KV,D]; index: scalar -> [B,1,H,D]."""
    b, _, h, d = q.shape
    l, kv = k.shape[1], k.shape[2]
    group = h // kv
    block_k = min(block_k, l)
    assert l % block_k == 0, "cache length must divide block_k"
    qh = q.reshape(b, h, 1, d)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    kv_steps = l // block_k
    kernel = functools.partial(
        _decode_kernel, scale=1.0 / math.sqrt(d), block_k=block_k,
        window=window, kv_steps=kv_steps,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, j_, idx: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, j_, idx, g=group: (b_, h_ // g, j_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, j_, idx, g=group: (b_, h_ // g, j_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b_, h_, j_, idx: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(index, jnp.int32).reshape(1), qh, kh, vh)
    return out.reshape(b, 1, h, d)
