"""Jit'd public wrapper for decode attention with CPU fallback."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k, v, index, *, window: int | None = None,
                     block_k: int = 512, force_kernel: bool = False):
    """Single-query decode attention. TPU -> Pallas; CPU -> oracle."""
    backend = jax.default_backend()
    if backend == "tpu":
        return decode_attention_pallas(q, k, v, index, window=window,
                                       block_k=block_k)
    if force_kernel:
        return decode_attention_pallas(q, k, v, index, window=window,
                                       block_k=block_k, interpret=True)
    return decode_attention_ref(q, k, v, index, window=window)
