"""Pure-jnp oracle for single-query decode attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, index, *, window: int | None = None):
    """q: [B,1,H,D]; k,v: [B,L,KV,D]; index: scalar current position.

    Attends to cache positions <= index (within the sliding window if set).
    Returns [B,1,H,D].
    """
    b, _, h, d = q.shape
    l, kv = k.shape[1], k.shape[2]
    group = h // kv
    qg = q.reshape(b, kv, group, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,blkd->bkgl", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    kj = jnp.arange(l)
    ok = kj <= index
    if window is not None:
        ok &= kj > index - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgl,blkd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)
