"""Jit'd public wrapper for flash attention with CPU fallback."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    force_kernel: bool = False) -> jax.Array:
    """Blocked attention. TPU -> Pallas; CPU -> oracle (interpret in tests)."""
    backend = jax.default_backend()
    if backend == "tpu":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k)
    if force_kernel:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=True)
    return attention_ref(q, k, v, causal=causal, window=window)
