"""Pallas TPU kernel: blocked causal/sliding-window attention (prefill).

FlashAttention-style online softmax.  Grid is (B, H, S_q/bq, S_kv/bk) with
the KV dimension innermost so the (m, l, acc) running statistics live in
VMEM scratch across KV steps.  GQA is handled **in the BlockSpec index map**
(head h reads KV head h // group) — the K/V tensors are never expanded to H
heads in HBM, which is the point of GQA.

Block sizes default to (bq, bk) = (128, 128): VMEM per step is
bq·d + 2·bk·d + bq·bk + accumulators ≈ 0.6 MiB fp32 at d = 128, and both
matmuls hit the 128x128 MXU natively.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int | None, kv_steps: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)               # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)               # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                                # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == kv_steps - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | None = None,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
) -> jax.Array:
    """q: [B,S,H,D]; k,v: [B,L,KV,D] -> [B,S,H,D]."""
    b, s, h, d = q.shape
    l, kv = k.shape[1], k.shape[2]
    group = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, l)
    assert s % block_q == 0 and l % block_k == 0, "pad seq to block multiple"
    # layout: heads-major [B,H,S,D] for contiguous per-head blocks
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    kv_steps = l // block_k
    grid = (b, h, s // block_q, kv_steps)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), block_q=block_q,
        block_k=block_k, causal=causal, window=window, kv_steps=kv_steps,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i_, j_: (b_, h_, i_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, i_, j_, g=group: (b_, h_ // g, j_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, i_, j_, g=group: (b_, h_ // g, j_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i_, j_: (b_, h_, i_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.transpose(0, 2, 1, 3)
