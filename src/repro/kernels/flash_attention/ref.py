"""Pure-jnp oracle for blocked attention (causal / sliding window, GQA)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int | None = None) -> jnp.ndarray:
    """q: [B,S,H,D]; k,v: [B,L,KV,D] (KV divides H). Returns [B,S,H,D]."""
    b, s, h, d = q.shape
    l, kv = k.shape[1], k.shape[2]
    group = h // kv
    qg = q.reshape(b, s, kv, group, d).astype(jnp.float32)
    scores = jnp.einsum("bskgd,blkd->bkgsl", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(l)[None, :]
    ok = jnp.ones((s, l), bool)
    if causal:
        ok &= kj <= qi
    if window is not None:
        ok &= kj > qi - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsl,blkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
