"""Pallas TPU kernels for the perf-critical compute hot-spots.

  ghost_norm       — per-example ||A^T G||_F^2 (DP-SGD ghost clipping)
  flash_attention  — blocked causal/sliding-window attention (prefill)
  decode_attention — single-query attention vs long KV (serving)

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with interpret=True against the pure-jnp oracles in each
``ref.py``.
"""
