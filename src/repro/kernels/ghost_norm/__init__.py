from repro.kernels.ghost_norm.ops import ghost_norm
from repro.kernels.ghost_norm.ref import ghost_norm_ref

__all__ = ["ghost_norm", "ghost_norm_ref"]
