"""Pure-jnp oracle for the ghost-norm kernel."""

from __future__ import annotations

import jax.numpy as jnp


def ghost_norm_ref(a: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Per-example ||A_i^T G_i||_F^2 for a dense layer y = a @ W.

    a: [B, S, d_in] activations; g: [B, S, d_out] output cotangents.
    ||A^T G||_F^2 = sum_{s,t} (a_s . a_t)(g_s . g_t).

    Returns [B] float32.
    """
    a32, g32 = a.astype(jnp.float32), g.astype(jnp.float32)
    aa = jnp.einsum("bsd,btd->bst", a32, a32)
    gg = jnp.einsum("bsd,btd->bst", g32, g32)
    return jnp.sum(aa * gg, axis=(1, 2))
