"""Jit'd public wrapper for the ghost-norm kernel with CPU fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ghost_norm.kernel import ghost_norm_pallas
from repro.kernels.ghost_norm.ref import ghost_norm_ref


def ghost_norm_blocked(a: jax.Array, g: jax.Array,
                       block: int = 256) -> jax.Array:
    """The kernel's algorithm in plain XLA: scan over (s, t) tiles so the
    Gram working set stays [B, bs, bt] instead of [B, S, S].  Used on
    non-TPU backends (and in the dry-run, so compile-time memory matches the
    TPU kernel's VMEM behaviour rather than the naive oracle's)."""
    b, s, _ = a.shape
    block = min(block, s)
    if s % block != 0:
        pad = block - s % block
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        g = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))
        s = a.shape[1]
    n = s // block
    a_t = a.reshape(b, n, block, a.shape[-1]).swapaxes(0, 1)
    g_t = g.reshape(b, n, block, g.shape[-1]).swapaxes(0, 1)

    def outer(acc, st):
        a_s, g_s = st  # [B, bs, d]

        def inner(acc2, tt):
            a_tt, g_tt = tt
            aa = jnp.einsum("bsd,btd->bst", a_s.astype(jnp.float32),
                            a_tt.astype(jnp.float32))
            gg = jnp.einsum("bsd,btd->bst", g_s.astype(jnp.float32),
                            g_tt.astype(jnp.float32))
            return acc2 + jnp.sum(aa * gg, axis=(1, 2)), None

        acc, _ = jax.lax.scan(inner, acc, (a_t, g_t))
        return acc, None

    out, _ = jax.lax.scan(outer, jnp.zeros((b,), jnp.float32), (a_t, g_t))
    return out


def ghost_norm(a: jax.Array, g: jax.Array, *, block_s: int = 128,
               block_t: int = 128, force_kernel: bool = False,
               prefer_oracle: bool = False) -> jax.Array:
    """Per-example ghost gradient sq-norms.

    TPU -> Pallas kernel; elsewhere -> the blocked XLA equivalent (same
    tiling, bounded memory); ``force_kernel`` runs interpret mode (tests).
    The naive ``[B, S, S]`` Gram oracle is opt-in via ``prefer_oracle``
    (debugging only): making it the short-sequence default meant the CPU
    path exercised a *different* memory profile than the kernel it stands
    in for, and its full-Gram materialisation dominates host memory exactly
    where the blocked path is cheapest.
    """
    backend = jax.default_backend()
    if backend == "tpu":
        return ghost_norm_pallas(a, g, block_s=block_s, block_t=block_t)
    if force_kernel:
        return ghost_norm_pallas(a, g, block_s=block_s, block_t=block_t,
                                 interpret=True)
    if prefer_oracle and a.ndim == 3:
        return ghost_norm_ref(a, g)
    return ghost_norm_blocked(a, g)
