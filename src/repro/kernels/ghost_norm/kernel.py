"""Pallas TPU kernel: per-example ghost gradient norms.

Computes n_b = sum_{s,t} (a_s . a_t)(g_s . g_t) without materialising the
[B, d_in, d_out] per-example weight gradients (Opacus' approach) or the full
[B, S, S] Gram matrices (the jnp oracle).  The (s, t) plane is tiled into
VMEM blocks; both Grams for a tile are two MXU matmuls, and the elementwise
product reduces into a per-example scalar accumulated across the grid.

VMEM working set per step: 2·(bs·d_in + bt·d_in + bs·d_out + bt·d_out) floats
plus two (bs, bt) tiles — e.g. bs = bt = 128, d = 4096 -> ~4.2 MiB fp32.
Arithmetic intensity vs the oracle: the oracle writes two [B,S,S] Grams to
HBM (O(B S^2) bytes); the kernel keeps them in VMEM (never leaves the core).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ghost_norm_kernel(a_s_ref, a_t_ref, g_s_ref, g_t_ref, out_ref):
    s_idx = pl.program_id(1)
    t_idx = pl.program_id(2)

    @pl.when((s_idx == 0) & (t_idx == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a_s = a_s_ref[0].astype(jnp.float32)   # [bs, d_in]
    a_t = a_t_ref[0].astype(jnp.float32)   # [bt, d_in]
    g_s = g_s_ref[0].astype(jnp.float32)   # [bs, d_out]
    g_t = g_t_ref[0].astype(jnp.float32)   # [bt, d_out]
    aa = jax.lax.dot_general(a_s, a_t, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [bs, bt]
    gg = jax.lax.dot_general(g_s, g_t, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [bs, bt]
    out_ref[0, 0] += jnp.sum(aa * gg)


@functools.partial(jax.jit, static_argnames=("block_s", "block_t", "interpret"))
def ghost_norm_pallas(
    a: jax.Array,
    g: jax.Array,
    *,
    block_s: int = 128,
    block_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """a: [B, S, d_in]; g: [B, S, d_out] -> [B] float32 ghost norms^2."""
    b, s, d_in = a.shape
    _, _, d_out = g.shape
    block_s = min(block_s, s)
    block_t = min(block_t, s)
    if s % block_s or s % block_t:
        pad_s = (-s) % block_s if s % block_s else 0
        pad = max(pad_s, (-s) % block_t)
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        g = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))
        s = a.shape[1]
    grid = (b, s // block_s, s // block_t)
    out = pl.pallas_call(
        _ghost_norm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, d_in), lambda b_, s_, t_: (b_, s_, 0)),
            pl.BlockSpec((1, block_t, d_in), lambda b_, s_, t_: (b_, t_, 0)),
            pl.BlockSpec((1, block_s, d_out), lambda b_, s_, t_: (b_, s_, 0)),
            pl.BlockSpec((1, block_t, d_out), lambda b_, s_, t_: (b_, t_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b_, s_, t_: (b_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(a, a, g, g)
    return out[:, 0]
