"""CLI: run any registered arm on any registered backend.

    python -m repro.run --arm decaph --backend sim --rounds 10
    python -m repro.run --list
    python -m repro.run --smoke          # every arm x every backend, tiny

Both axes come from registries (``repro.arms`` and ``repro.arms.backends``):
a newly registered arm or backend joins ``--list``, the ``--backend``
choices and the ``--smoke`` matrix with zero wiring here.  The smoke mode is
what CI runs: a broken registration or a backend contract violation fails
in seconds instead of surfacing as a corrupted benchmark table.  Pairs the
capability records rule out (e.g. a node arm on a fused-only backend) are
*skipped* — that is negotiation working — and a backend whose device
requirements this process cannot meet is skipped with the requirement
printed.
"""

from __future__ import annotations

import argparse
import sys

import repro.arms as arms
import repro.obs as obs
from repro.arms import backends as backends_lib
from repro.core.dp import DPConfig
from repro.data.synthetic import make_gemini_like
# re-exported for pre-refactor callers; canonical home is the model zoo
from repro.models.tabular import linear_model, pooled_accuracy  # noqa: F401
from repro.sim.nodes import heterogeneous_trace, nodes_from_trace


def run_one(arm_name: str, backend: str, *, rounds: int, hospitals: int,
            features: int, examples: int, batch: int, seed: int,
            sigma: float, use_secagg: bool = True) -> arms.RunReport:
    silos = arms.normalize_participants(
        make_gemini_like(seed=seed, n_total=examples, n_silos=hospitals,
                         n_features=features)
    )
    model = linear_model(features)
    cfg = arms.ArmConfig(
        rounds=rounds, batch_size=batch, lr=0.4, seed=seed,
        use_secagg=use_secagg,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=sigma, microbatch_size=8),
    )
    nodes = None
    if backends_lib.get_backend(backend).info.supports_sim_time:
        nodes = nodes_from_trace(heterogeneous_trace(hospitals))
    report = arms.run(arm_name, model, silos, cfg, backend=backend,
                      nodes=nodes)
    report_acc = pooled_accuracy(model, report.params, silos)
    line = (f"{arm_name:<10} {backend:<5} rounds={report.rounds_completed:<4}"
            f" eps={report.epsilon:8.3f} loss={report.mean_loss():8.4f}"
            f" acc={report_acc:.3f}")
    if report.timing is not None:
        line += (f" | sim_wall={report.timing.wall_clock:9.3f}s"
                 f" wire={report.timing.bytes_on_wire:12.0f}B"
                 f" dropouts={report.timing.dropout_events}"
                 f" recoveries={report.timing.recoveries}")
    print(line)
    return report


def _smoke() -> int:
    """Every registered arm x every runnable registered backend."""
    failures = []
    registry = backends_lib.backend_registry()
    unavailable = {name: backends_lib.availability(name) for name in registry}
    for name, reason in unavailable.items():
        if reason:
            print(f"[smoke] backend {name!r} skipped here: {reason}",
                  file=sys.stderr)
    for name in arms.names():
        arm_cls = arms.get(name)
        for backend, info in registry.items():
            if unavailable[backend]:
                continue
            # negotiate: secure uploads only where the backend runs SecAgg
            use_secagg = info.supports_secagg
            ruled_out = backends_lib.compatibility_error(
                arm_cls, info, use_secagg=use_secagg
            )
            if ruled_out is not None:
                print(f"{name:<10} {backend:<5} ruled out: {ruled_out}")
                continue
            try:
                rep = run_one(
                    name, backend, rounds=3, hospitals=4, features=8,
                    examples=240, batch=32, seed=0, sigma=0.8,
                    use_secagg=use_secagg,
                )
                if rep.rounds_completed < 1:
                    raise RuntimeError("completed zero rounds")
            except Exception as e:  # noqa: BLE001 - smoke must report all
                failures.append(f"{name}/{backend}: {e}")
                print(f"{name:<10} {backend:<5} FAILED: {e}",
                      file=sys.stderr)
    if failures:
        print(f"\n{len(failures)} arm/backend smoke failures",
              file=sys.stderr)
        return 1
    print("\nall registered arms passed on every runnable backend")
    return 0


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.run",
        description="Run a registered federation arm on a registered backend.",
    )
    p.add_argument("--arm", choices=arms.names(), help="arm to run")
    p.add_argument("--backend", choices=backends_lib.backend_names(),
                   default=backends_lib.DEFAULT_BACKEND)
    p.add_argument("--rounds", type=int, default=10)
    p.add_argument("--hospitals", type=int, default=5)
    p.add_argument("--features", type=int, default=32)
    p.add_argument("--examples", type=int, default=1200)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--sigma", type=float, default=0.8,
                   help="DP noise multiplier (private arms)")
    p.add_argument("--list", action="store_true",
                   help="print registered arms + backends and exit")
    p.add_argument("--smoke", action="store_true",
                   help="every registered arm x every registered backend, "
                        "tiny shapes")
    p.add_argument("--obs", default=None, metavar="DIR",
                   help="record obs spans/counters + privacy ledger and "
                        "export events/ledger/Chrome trace into DIR")
    args = p.parse_args(argv)

    if args.list:
        print("arms:")
        for name in arms.names():
            cls = arms.get(name)
            print(f"  {name:<10} mode={cls.mode:<6} "
                  f"topology={cls.topology_kind:<5} private={cls.private}")
        print("backends:")
        for name, info in backends_lib.backend_registry().items():
            reason = backends_lib.availability(name)
            caps = (f"fused={info.supports_fused} "
                    f"secagg={info.supports_secagg} "
                    f"sim_time={info.supports_sim_time} "
                    f"group={info.bit_exact_group or '-'}")
            note = f"  [unavailable here: {reason}]" if reason else ""
            print(f"  {name:<10} {caps}{note}")
        return 0

    if args.smoke:
        return _smoke()

    if not args.arm:
        p.error("--arm is required (or use --list / --smoke)")
    rec = obs.enable() if args.obs else None
    run_one(args.arm, args.backend, rounds=args.rounds,
            hospitals=args.hospitals, features=args.features,
            examples=args.examples, batch=args.batch, seed=args.seed,
            sigma=args.sigma,
            use_secagg=backends_lib.get_backend(
                args.backend).info.supports_secagg)
    if rec is not None:
        paths = obs.export(args.obs, rec)
        obs.disable()
        print(f"obs: wrote {', '.join(str(v) for v in paths.values())}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
