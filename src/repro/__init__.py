"""repro: DeCaPH (Decentralised, Collaborative, Privacy-preserving ML) on JAX/TPU.

Top-level package for the production framework reproducing and extending
Fang et al., "Decentralised, Collaborative, and Privacy-preserving Machine
Learning for Multi-Hospital Data" (eBioMedicine 2024).
"""

__version__ = "0.1.0"
