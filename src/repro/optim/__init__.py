"""Pure-pytree optimizers (no optax dependency)."""

from repro.optim.optimizers import (
    Optimizer,
    adafactor,
    adamw,
    momentum,
    sgd,
    get_optimizer,
)

__all__ = ["Optimizer", "adafactor", "adamw", "momentum", "sgd", "get_optimizer"]
