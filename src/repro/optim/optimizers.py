"""Optimizers as (init, update) pairs over parameter pytrees.

Kept deliberately optax-shaped: ``update(grads, state, params) ->
(new_params, new_state)``.  Adafactor matters at pod scale — factored second
moments cut optimizer HBM from 8 B/param (Adam) to O(rows+cols), which is what
lets the 340B/671B assigned configs fit the production mesh (see DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    name: str = "opt"


def sgd(lr: float, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        new = jax.tree_util.tree_map(
            lambda p, g: p - lr * (g.astype(p.dtype) + weight_decay * p),
            params, grads,
        )
        return new, state

    return Optimizer(init, update, "sgd")


def momentum(lr: float, beta: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params):
        new_m = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads
        )
        new_p = jax.tree_util.tree_map(
            lambda p, m: p - lr * (m.astype(p.dtype) + weight_decay * p),
            params, new_m,
        )
        return new_p, new_m

    return Optimizer(init, update, "momentum")


class AdamState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jax.Array


def adamw(
    lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamState(
            jax.tree_util.tree_map(zeros, params),
            jax.tree_util.tree_map(zeros, params),
            jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params):
        count = state.count + 1
        c = count.astype(jnp.float32)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads,
        )
        mu_hat_scale = 1.0 / (1 - b1**c)
        nu_hat_scale = 1.0 / (1 - b2**c)

        def upd(p, m, v):
            step = lr * (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
            return (p - (step + lr * weight_decay * p).astype(p.dtype)).astype(p.dtype)

        new_p = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_p, AdamState(mu, nu, count)

    return Optimizer(init, update, "adamw")


class AdafactorState(NamedTuple):
    vr: PyTree      # row factors (or full v for <2D leaves)
    vc: PyTree      # col factors (or () sentinel)
    count: jax.Array


def adafactor(
    lr: float = 1e-2,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Factored Adafactor (Shazeer & Stern 2018), fp32 factors.

    Matrices store row+col second-moment factors; vectors/scalars store full
    second moments.  No first moment (beta1=0) — the memory-lean setting.
    """

    def _is_matrix(p):
        return p.ndim >= 2

    def init(params):
        def vr_init(p):
            if _is_matrix(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros_like(p, jnp.float32)

        def vc_init(p):
            if _is_matrix(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(
            jax.tree_util.tree_map(vr_init, params),
            jax.tree_util.tree_map(vc_init, params),
            jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params):
        count = state.count + 1
        c = count.astype(jnp.float32)
        beta2 = 1.0 - c ** (-decay)

        def upd(p, g, vr, vc):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _is_matrix(p):
                new_vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                new_vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                r = new_vr / jnp.mean(new_vr, axis=-1, keepdims=True)
                u = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(new_vc)[..., None, :])
            else:
                new_vr = beta2 * vr + (1 - beta2) * g2
                new_vc = vc
                u = g32 / jnp.sqrt(new_vr)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            new_p = p - (lr * u + lr * weight_decay * p.astype(jnp.float32)).astype(p.dtype)
            return new_p.astype(p.dtype), new_vr, new_vc

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_vr = treedef.flatten_up_to(state.vr)
        flat_vc = treedef.flatten_up_to(state.vc)
        out = [upd(p, g, vr, vc) for p, g, vr, vc in zip(flat_p, flat_g, flat_vr, flat_vc)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_vr = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        new_vc = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        return new_p, AdafactorState(new_vr, new_vc, count)

    return Optimizer(init, update, "adafactor")


def get_optimizer(name: str, lr: float, weight_decay: float = 0.0, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, weight_decay)
    if name == "momentum":
        return momentum(lr, weight_decay=weight_decay, **kw)
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay, **kw)
    if name == "adafactor":
        return adafactor(lr, weight_decay=weight_decay, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
