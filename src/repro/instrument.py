"""Jit dispatch accounting shared by the training and serving hot paths.

``instrumented_jit`` is ``jax.jit`` plus a process-wide program-launch
counter.  It started life inside ``repro.arms.fused`` (DESIGN.md §7) where
``benchmarks/hotpath.py`` uses it to certify the fused round-step's
O(1)-dispatches-per-round contract; the serving tier (``repro.serve``,
DESIGN.md §9) asserts the same invariant for steady-state decode — one
program launch per token — so the counter lives here, neutral ground
below both subsystems.  ``repro.arms.fused`` re-exports every name, so
arm code and benchmarks keep importing it from there.

The count is a structural metric, not a timer: eager jnp ops are not
included, so it measures "how many compiled programs does this phase
launch" — O(H) on the legacy round loop vs O(1) fused; O(prompt_len) on
the legacy Python prefill vs O(1) on the jitted prefill program.

``execution_context`` routes every instrumented call through an installed
executor (the SPMD ``MeshExecutor`` in ``launch/federated.py``) so a mesh
backend can re-stage the same program with explicit shardings.
"""

from __future__ import annotations

import contextlib
import functools
from typing import Callable

import jax

_jit_dispatch_count = 0

# Active cohort-program executor (DESIGN.md §8).  ``None`` means plain jit on
# the default device; an SPMD backend installs a ``launch.federated``
# MeshExecutor for the duration of each fused round, which re-dispatches the
# same program onto a device mesh with explicit shardings.
_EXECUTOR = None


@contextlib.contextmanager
def execution_context(executor):
    """Route every ``instrumented_jit`` call through ``executor`` while open."""
    global _EXECUTOR
    prev, _EXECUTOR = _EXECUTOR, executor
    try:
        yield
    finally:
        _EXECUTOR = prev


def active_executor():
    return _EXECUTOR


def instrumented_jit(fn: Callable, **jit_kwargs) -> Callable:
    """``jax.jit`` that counts program launches (``jit_dispatches()``).

    The wrapper carries the raw ``fn`` and its jit kwargs so a mesh
    executor (``execution_context``) can re-stage the same program with
    explicit shardings instead of the plain single-device jit.
    """
    compiled = jax.jit(fn, **jit_kwargs)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        global _jit_dispatch_count
        _jit_dispatch_count += 1
        if _EXECUTOR is not None:
            return _EXECUTOR.execute(wrapper, args, kwargs)
        return compiled(*args, **kwargs)

    wrapper.jitted = compiled
    wrapper.fn = fn
    wrapper.jit_kwargs = dict(jit_kwargs)
    return wrapper


def instrumented_jit_pair(fn: Callable, *, reduced_pos: int = 1,
                          **jit_kwargs) -> tuple[Callable, Callable]:
    """(full, slim) jits of a cohort function whose output tuple carries the
    in-jit cohort reduction at ``reduced_pos``.  The slim variant drops that
    output, so XLA dead-code-eliminates the reduction entirely — backends
    that can't consume it (sim transport, SecAgg uploads) don't pay for it.
    """

    def dropped(*args, **kwargs):
        out = fn(*args, **kwargs)
        return out[:reduced_pos] + out[reduced_pos + 1:]

    return (
        instrumented_jit(fn, **jit_kwargs),
        instrumented_jit(dropped, **jit_kwargs),
    )


def jit_dispatches() -> int:
    """Total instrumented jit program launches since the last reset."""
    return _jit_dispatch_count


def reset_jit_dispatches() -> None:
    global _jit_dispatch_count
    _jit_dispatch_count = 0
