"""Jit dispatch accounting shared by the training and serving hot paths.

``instrumented_jit`` is ``jax.jit`` plus a process-wide program-launch
counter.  It started life inside ``repro.arms.fused`` (DESIGN.md §7) where
``benchmarks/hotpath.py`` uses it to certify the fused round-step's
O(1)-dispatches-per-round contract; the serving tier (``repro.serve``,
DESIGN.md §9) asserts the same invariant for steady-state decode — one
program launch per token — so the counter lives here, neutral ground
below both subsystems.  ``repro.arms.fused`` re-exports every name, so
arm code and benchmarks keep importing it from there.

The count is a structural metric, not a timer: eager jnp ops are not
included, so it measures "how many compiled programs does this phase
launch" — O(H) on the legacy round loop vs O(1) fused; O(prompt_len) on
the legacy Python prefill vs O(1) on the jitted prefill program.

Thread-safety: ``python -m repro.serve --train-rounds N`` runs a trainer
thread concurrently with the decode loop, so both paths dispatch through
this module at once.  The counter increments under a lock (an unguarded
``+= 1`` loses ticks under contention, which would fake sub-O(1) dispatch
rates in the benchmarks), and the active executor is **thread-local**: a
mesh backend's ``execution_context`` install is visible only to the
thread that opened it, so a concurrent serving thread can never be routed
through another thread's mesh executor.

When ``repro.obs`` recording is enabled, every dispatch additionally
feeds the process recorder: a ``jit_dispatches`` counter event plus a
``jit_dispatch`` span bracketing the launch, which is what lets obs phase
breakdowns attribute wall time to compiled-program dispatch.  Recording
off means exactly the pre-obs behavior (a lock, an int, nothing else).

``execution_context`` routes every instrumented call through an installed
executor (the SPMD ``MeshExecutor`` in ``launch/federated.py``) so a mesh
backend can re-stage the same program with explicit shardings.
"""

from __future__ import annotations

import contextlib
import functools
import threading
from typing import Callable

import jax

import repro.obs as obs

_count_lock = threading.Lock()
_jit_dispatch_count = 0  # guarded by _count_lock

# Active cohort-program executor (DESIGN.md §8), per-thread.  ``None`` means
# plain jit on the default device; an SPMD backend installs a
# ``launch.federated`` MeshExecutor for the duration of each fused round,
# which re-dispatches the same program onto a device mesh with explicit
# shardings.
_tls = threading.local()


@contextlib.contextmanager
def execution_context(executor):
    """Route this THREAD's ``instrumented_jit`` calls through ``executor``
    while open (other threads keep their own executor, or none)."""
    prev = getattr(_tls, "executor", None)
    _tls.executor = executor
    try:
        yield
    finally:
        _tls.executor = prev


def active_executor():
    return getattr(_tls, "executor", None)


def instrumented_jit(fn: Callable, **jit_kwargs) -> Callable:
    """``jax.jit`` that counts program launches (``jit_dispatches()``).

    The wrapper carries the raw ``fn`` and its jit kwargs so a mesh
    executor (``execution_context``) can re-stage the same program with
    explicit shardings instead of the plain single-device jit.
    """
    compiled = jax.jit(fn, **jit_kwargs)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        global _jit_dispatch_count
        with _count_lock:
            _jit_dispatch_count += 1
        executor = getattr(_tls, "executor", None)
        t0 = obs.now()  # None when recording is off
        if executor is not None:
            out = executor.execute(wrapper, args, kwargs)
        else:
            out = compiled(*args, **kwargs)
        if t0 is not None:
            obs.complete("jit_dispatch", t0, cat="jit",
                         fn=getattr(fn, "__name__", "<fn>"))
            obs.counter("jit_dispatches", 1)
        return out

    wrapper.jitted = compiled
    wrapper.fn = fn
    wrapper.jit_kwargs = dict(jit_kwargs)
    return wrapper


def instrumented_jit_pair(fn: Callable, *, reduced_pos: int = 1,
                          **jit_kwargs) -> tuple[Callable, Callable]:
    """(full, slim) jits of a cohort function whose output tuple carries the
    in-jit cohort reduction at ``reduced_pos``.  The slim variant drops that
    output, so XLA dead-code-eliminates the reduction entirely — backends
    that can't consume it (sim transport, SecAgg uploads) don't pay for it.
    """

    def dropped(*args, **kwargs):
        out = fn(*args, **kwargs)
        return out[:reduced_pos] + out[reduced_pos + 1:]

    return (
        instrumented_jit(fn, **jit_kwargs),
        instrumented_jit(dropped, **jit_kwargs),
    )


def jit_dispatches() -> int:
    """Total instrumented jit program launches since the last reset."""
    with _count_lock:
        return _jit_dispatch_count


def reset_jit_dispatches() -> None:
    global _jit_dispatch_count
    with _count_lock:
        _jit_dispatch_count = 0
