"""``python -m repro.scenarios`` — list, run, sweep, report.

    python -m repro.scenarios --list
    python -m repro.scenarios --run gemini-5hospital
    python -m repro.scenarios --sweep capacity-mini
    python -m repro.scenarios --sweep smoke-2x2 --assert-cached
    python -m repro.scenarios --report capacity-mini

``--sweep`` executes through the content-addressed cache (``--cache-dir``),
so a re-run only executes new/changed cells; ``--assert-cached`` turns a
fully-cached expectation into an exit code for CI.  ``--report`` re-renders
artifacts from cache alone, without executing anything.
"""

from __future__ import annotations

import argparse
import os
import sys

import repro.obs as obs
from repro.scenarios import grid as grid_lib
from repro.scenarios import presets as presets_lib
from repro.scenarios import report as report_lib
from repro.scenarios.cache import DEFAULT_CACHE_DIR, ResultCache
from repro.scenarios.executor import run_sweep


def _default_jobs() -> int:
    return min(4, os.cpu_count() or 1)


def _print_list() -> None:
    print("presets:")
    for name, spec in sorted(presets_lib.all_presets().items()):
        print(f"  {name:<24} task={spec.task:<9} H={spec.hospitals:<3} "
              f"size={spec.model_size:<7} tags={','.join(spec.tags)}")
    print("\nsweeps:")
    for name in sorted(grid_lib.SWEEPS):
        g = grid_lib.get_sweep(name)
        axes = ", ".join(f"{k}x{len(v)}" for k, v in sorted(g.axes.items()))
        print(f"  {name:<24} {g.size():>4} cells  ({axes})")


def _emit_artifacts(out_path: str, sweep_name: str, cells) -> None:
    out_json, out_md = report_lib.write_artifacts(sweep_name, cells, out_path)
    print(report_lib.markdown_report(sweep_name, cells))
    print(f"wrote {out_json} and {out_md}", file=sys.stderr)


def _sweep_cells(args, specs, sweep_name: str, default_out: str) -> int:
    cache = ResultCache(args.cache_dir)
    outcome = run_sweep(
        specs, cache, jobs=args.jobs, force=args.force,
        progress=lambda msg: print(msg, file=sys.stderr),
    )
    print(f"sweep {sweep_name}: {outcome.cells} cells "
          f"({outcome.hits} cached, {outcome.misses} ran) "
          f"in {outcome.elapsed:.1f}s", file=sys.stderr)
    _emit_artifacts(args.out or default_out, sweep_name, outcome.results)
    if args.assert_cached and outcome.misses:
        print(f"--assert-cached: {outcome.misses} cells were NOT served "
              "from cache", file=sys.stderr)
        return 1
    return 0


def _export_obs(args) -> None:
    if args.obs and obs.recorder() is not None:
        paths = obs.export(args.obs)
        obs.disable()
        print(f"obs: wrote {', '.join(str(v) for v in paths.values())}",
              file=sys.stderr)


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.scenarios",
        description="Declarative scenario suite + cached parallel sweeps.",
    )
    act = p.add_mutually_exclusive_group(required=True)
    act.add_argument("--list", action="store_true",
                     help="list presets and named sweeps")
    act.add_argument("--run", metavar="PRESET",
                     help="run one named preset (through the cache)")
    act.add_argument("--sweep", metavar="SWEEP",
                     help="run a named sweep (only cache misses execute)")
    act.add_argument("--report", metavar="SWEEP",
                     help="re-render a sweep's artifacts from cache only")
    p.add_argument("--cache-dir", default=DEFAULT_CACHE_DIR,
                   help=f"result cache directory (default {DEFAULT_CACHE_DIR})")
    p.add_argument("--jobs", type=int, default=_default_jobs(),
                   help="process-pool width for cache misses (1 = inline)")
    p.add_argument("--out", default=None,
                   help="artifact path, markdown lands beside it (default: "
                        "BENCH_sweep.json for --sweep/--report, "
                        "BENCH_run.json for --run — so one-off runs never "
                        "clobber the committed sweep trajectory)")
    p.add_argument("--force", action="store_true",
                   help="ignore cached results and re-run every cell")
    p.add_argument("--assert-cached", action="store_true",
                   help="exit 1 if any cell had to execute (CI cache check)")
    p.add_argument("--arm", help="override the arm for --run")
    p.add_argument("--obs", default=None, metavar="DIR",
                   help="record obs spans (per-cell phase breakdowns in the "
                        "BENCH rows) and export artifacts into DIR; "
                        "inline cells only — pool workers do not record")
    args = p.parse_args(argv)
    if args.obs:
        obs.enable()

    if args.list:
        _print_list()
        return 0

    if args.run:
        spec = presets_lib.get_preset(args.run)
        if args.arm:
            spec = spec.replace(arm=args.arm,
                                name=f"{spec.name}/arm={args.arm}")
        rc = _sweep_cells(args, [spec], spec.name, "BENCH_run.json")
        _export_obs(args)
        return rc

    if args.sweep:
        specs = grid_lib.get_sweep(args.sweep).specs()
        rc = _sweep_cells(args, specs, args.sweep, "BENCH_sweep.json")
        _export_obs(args)
        return rc

    # --report: cache-only re-render
    sweep = grid_lib.get_sweep(args.report)
    cache = ResultCache(args.cache_dir)
    cells, missing = [], []
    for spec in sweep.specs():
        cached = cache.get(spec)
        (cells.append(cached) if cached is not None
         else missing.append(spec.name))
    if missing:
        print(f"{len(missing)} of {sweep.size()} cells are not cached "
              f"(first: {missing[0]}); run --sweep {args.report} first",
              file=sys.stderr)
        return 1
    _emit_artifacts(args.out or "BENCH_sweep.json", args.report, cells)
    return 0
