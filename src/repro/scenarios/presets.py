"""Named, paper-grounded scenario presets.

One preset per (case study x model size) — GEMINI-like mortality, pancreas
single-cell typing, chest X-ray multilabel — plus the canonical 5-hospital
heterogeneous deployment trace that ``benchmarks/sim_report.py`` and
``examples/heterogeneous_hospitals.py`` previously each hard-coded.  That
trace now exists exactly once, here.

Model/data builders live here too, lazily importing the JAX-backed modules,
so the executor stays a thin orchestration layer and importing this module
(preset listing, sweep expansion) never builds a model or a cohort.
"""

from __future__ import annotations

from repro.scenarios.spec import ScenarioSpec

# ---------------------------------------------------------------------------
# The canonical 5-hospital deployment trace (single source of truth).
# A fast research centre down to a community-hospital straggler
# (examples/sec), the straggler also riding the slowest WAN links, and a
# flaky mid-tier site that drops off mid-run and rejoins — the dropout lands
# mid-round, which is what exercises SecAgg's Shamir mask recovery.
# ---------------------------------------------------------------------------

FIVE_HOSPITAL_NODES: list[dict] = [
    {"throughput": 500.0, "overhead": 0.02},
    {"throughput": 300.0, "overhead": 0.02},
    {"throughput": 180.0, "overhead": 0.03},
    {"throughput": 110.0, "overhead": 0.04,
     "dropouts": [[0.35, 2.5]]},          # flaky: drops mid-run, rejoins
    {"throughput": 60.0, "overhead": 0.05},
]

FIVE_HOSPITAL_TOPOLOGY: dict = {
    "kind": "full",
    "default": {"bandwidth": 12.5e6, "latency": 0.02},
    "links": {"0-4": {"bandwidth": 1.25e6, "latency": 0.08},
              "1-4": {"bandwidth": 1.25e6, "latency": 0.08}},
}

FIVE_HOSPITAL_TRACE: dict = {
    "nodes": FIVE_HOSPITAL_NODES,
    "topology": FIVE_HOSPITAL_TOPOLOGY,
}

# WAN churn on top of the same trace: the straggler's main link degrades,
# then fails outright, then is restored — a LinkSchedule consumed through
# Topology.from_trace (satellite of ISSUE 3).
FIVE_HOSPITAL_CHURN_SCHEDULE: list[dict] = [
    {"t": 0.8, "link": "0-4", "bandwidth": 1.25e5, "latency": 0.4},
    {"t": 1.6, "link": "0-4", "down": True},
    {"t": 4.0, "link": "0-4", "bandwidth": 1.25e6, "latency": 0.08},
]


def _five_hospital_churn_topology() -> dict:
    topo = dict(FIVE_HOSPITAL_TOPOLOGY)
    topo["schedule"] = list(FIVE_HOSPITAL_CHURN_SCHEDULE)
    return topo


# ---------------------------------------------------------------------------
# Model-size ladders per case study.
# ---------------------------------------------------------------------------

_FEATURES: dict[tuple[str, str], int] = {
    # GEMINI EHR: 436 one-hot+numeric features at full paper scale
    ("gemini", "small"): 32,
    ("gemini", "medium"): 128,
    ("gemini", "full"): 436,
    # pancreas scRNA: 15,558 genes at full paper scale
    ("pancreas", "small"): 128,
    ("pancreas", "medium"): 1024,
    ("pancreas", "full"): 15558,
    # X-ray: feature = image side length
    ("xray", "small"): 16,
    ("xray", "medium"): 24,
    ("xray", "full"): 32,
    # LM: feature = sequence length
    ("lm", "small"): 16,
    ("lm", "medium"): 32,
    ("lm", "full"): 64,
}

N_PANCREAS_TYPES = 4
N_XRAY_LABELS = 4

# Transformer ladder for the "lm" task: dense decoder stacks (smollm-family
# smoke config rescaled), untied embeddings so the ghost clipping path is
# exact and the GhostCapability attaches (DESIGN.md §12).  Head/FFN/vocab
# dims stay divisible by the debug pod mesh's model extent (2) so TP
# sharding engages on the shard backend.
_LM_DIMS: dict[str, dict] = {
    "small": dict(d_model=64, n_layers=2, n_heads=2, n_kv_heads=1,
                  head_dim=32, d_ff=128, vocab_size=256),
    "medium": dict(d_model=128, n_layers=2, n_heads=4, n_kv_heads=2,
                   head_dim=32, d_ff=256, vocab_size=512),
    "full": dict(d_model=256, n_layers=4, n_heads=8, n_kv_heads=4,
                 head_dim=32, d_ff=512, vocab_size=1024),
}


def lm_model_config(model_size: str):
    """The transformer ModelConfig behind an "lm" preset size."""
    from repro.configs import get_smoke_config
    from repro.configs.base import dense_stack

    dims = dict(_LM_DIMS[model_size])
    n_layers = dims.pop("n_layers")
    return get_smoke_config("smollm-360m").replace(
        n_layers=n_layers, stack=dense_stack(n_layers),
        tie_embeddings=False, **dims,
    )


def lm_seq_len(model_size: str) -> int:
    """The "lm" preset's sequence length for a model size (feature ladder)."""
    return _FEATURES[("lm", model_size)]


def normalizes(task: str) -> bool:
    """Whether the task's silos go through ``normalize_participants``.

    Token ids are categorical — feature-standardising them would destroy
    the data — so the "lm" task opts out.
    """
    return task != "lm"


def default_features(task: str, model_size: str) -> int:
    return _FEATURES[(task, model_size)]


def resolved_features(spec: ScenarioSpec) -> int:
    return spec.features or default_features(spec.task, spec.model_size)


def build_model(spec: ScenarioSpec):
    """The preset model for ``spec`` (paper architectures at three scales)."""
    from repro.models import tabular

    if spec.task == "lm":
        from repro.serve.federation import transformer_model

        return transformer_model(lm_model_config(spec.model_size))
    f = resolved_features(spec)
    if spec.task == "gemini":
        if spec.model_size == "small":
            return tabular.linear_model(f)
        if spec.model_size == "medium":
            return tabular.make_mlp_classifier([f, 64, 1], task="binary")
        # paper: MLP 436-300-100-50-10-1
        return tabular.make_mlp_classifier([f, 300, 100, 50, 10, 1],
                                           task="binary")
    if spec.task == "pancreas":
        sizes = {
            "small": [f, 32, N_PANCREAS_TYPES],
            "medium": [f, 256, 32, N_PANCREAS_TYPES],
            # paper: MLP 15558-1000-100-4
            "full": [f, 1000, 100, N_PANCREAS_TYPES],
        }[spec.model_size]
        return tabular.make_mlp_classifier(sizes, task="multiclass")
    # xray: BN-free mini-DenseNet ladder (paper uses DenseNet121)
    cfg = {
        "small": tabular.DenseNetConfig(growth=4, blocks=(1, 1),
                                        init_channels=8, image_size=f),
        "medium": tabular.DenseNetConfig(growth=8, blocks=(2, 2),
                                         init_channels=12, image_size=f),
        "full": tabular.DenseNetConfig(image_size=f),
    }[spec.model_size]
    return tabular.make_densenet(cfg)


def build_silos(spec: ScenarioSpec):
    """The preset cohort for ``spec`` (synthetic, paper-statistics-matched)."""
    from repro.data import synthetic

    f = resolved_features(spec)
    if spec.task == "lm":
        from repro.serve.federation import token_silos

        return token_silos(
            lm_model_config(spec.model_size), hospitals=spec.hospitals,
            n_per=max(1, spec.examples // spec.hospitals), seq_len=f,
            seed=spec.seed,
        )
    if spec.task == "gemini":
        return synthetic.make_gemini_like(
            seed=spec.seed, n_total=spec.examples, n_silos=spec.hospitals,
            n_features=f,
        )
    if spec.task == "pancreas":
        return synthetic.make_pancreas_like(
            seed=spec.seed, n_total=spec.examples, n_silos=spec.hospitals,
            n_genes=f, n_types=N_PANCREAS_TYPES,
        )
    return synthetic.make_xray_like(
        seed=spec.seed, n_total=spec.examples, n_silos=spec.hospitals,
        image_size=f,
    )


def pooled_metric(spec: ScenarioSpec, model, params, silos) -> float:
    """Task-appropriate pooled utility in [0, 1]."""
    if spec.task == "lm":              # pooled next-token accuracy
        import jax.numpy as jnp
        import numpy as np

        from repro.models import transformer as tf

        cfg = lm_model_config(spec.model_size)
        x = np.concatenate([p.x for p in silos])
        y = np.concatenate([p.y for p in silos])
        logits, _aux = tf.forward(
            cfg, params, {"tokens": jnp.asarray(x, jnp.int32)}
        )
        pred = np.asarray(jnp.argmax(logits, axis=-1))
        mask = y >= 0
        return float((pred[mask] == y[mask]).mean())
    if spec.task == "pancreas":        # multiclass: argmax accuracy
        import jax.numpy as jnp
        import numpy as np

        x = np.concatenate([p.x for p in silos])
        y = np.concatenate([p.y for p in silos])
        pred = np.asarray(model.predict_fn(params, jnp.asarray(x)))
        return float((pred.argmax(-1) == y).mean())
    # gemini (binary) and xray (multilabel, elementwise) share the
    # thresholded pooled accuracy — one implementation, in the model zoo
    from repro.models.tabular import pooled_accuracy

    return pooled_accuracy(model, params, silos)


def default_nodes(spec: ScenarioSpec) -> list[dict]:
    """Derived node trace when the spec gives none: uniform cohort with a
    configurable straggler fraction (each straggler 8x slower)."""
    if spec.nodes is not None:
        return spec.nodes
    n_strag = int(round(spec.straggler_ratio * spec.hospitals))
    return [
        {"throughput": spec.throughput / (8.0 if i >= spec.hospitals - n_strag
                                          else 1.0),
         "overhead": 0.02}
        for i in range(spec.hospitals)
    ]


# ---------------------------------------------------------------------------
# The preset registry.
# ---------------------------------------------------------------------------

_EXAMPLES = {
    # total cohort examples per (task, size): big enough to learn, small
    # enough that `--run` finishes in seconds at "small"
    ("gemini", "small"): 1200,
    ("gemini", "medium"): 2400,
    ("gemini", "full"): 5000,
    ("pancreas", "small"): 600,
    ("pancreas", "medium"): 1200,
    ("pancreas", "full"): 2600,
    ("xray", "small"): 300,
    ("xray", "medium"): 600,
    ("xray", "full"): 1800,
    ("lm", "small"): 96,
    ("lm", "medium"): 128,
    ("lm", "full"): 192,
}

# paper silo counts; lm = 4 so cohorts divide the debug pod mesh's
# ("pod", "data") extent and the hospital axis shards across pods
_HOSPITALS = {"gemini": 8, "pancreas": 5, "xray": 3, "lm": 4}


def _case_study_presets() -> dict[str, ScenarioSpec]:
    out: dict[str, ScenarioSpec] = {}
    for task in ("gemini", "pancreas", "xray"):
        for size in ("small", "medium", "full"):
            name = f"{task}-{size}"
            out[name] = ScenarioSpec(
                name=name, task=task, model_size=size,
                hospitals=_HOSPITALS[task],
                examples=_EXAMPLES[(task, size)],
                rounds=12, batch_size=64, lr=0.4,
                tags=("case-study", task, size),
            )
    return out


def all_presets() -> dict[str, ScenarioSpec]:
    """All named presets (fresh spec objects each call)."""
    out = _case_study_presets()
    for size in ("small", "medium", "full"):
        name = f"lm-{size}"
        out[name] = ScenarioSpec(
            name=name, task="lm", model_size=size,
            hospitals=_HOSPITALS["lm"], examples=_EXAMPLES[("lm", size)],
            rounds=8, batch_size=16, lr=0.1, use_secagg=False,
            tags=("case-study", "lm", size, "transformer"),
        )
    out["gemini-5hospital"] = ScenarioSpec(
        name="gemini-5hospital", task="gemini", model_size="small",
        hospitals=5, examples=1200, rounds=12, batch_size=64, lr=0.4,
        nodes=[dict(n) for n in FIVE_HOSPITAL_NODES],
        topology=dict(FIVE_HOSPITAL_TOPOLOGY),
        tags=("deployment", "heterogeneous"),
    )
    out["gemini-5hospital-churn"] = ScenarioSpec(
        name="gemini-5hospital-churn", task="gemini", model_size="small",
        hospitals=5, examples=1200, rounds=12, batch_size=64, lr=0.4,
        nodes=[dict(n) for n in FIVE_HOSPITAL_NODES],
        topology=_five_hospital_churn_topology(),
        tags=("deployment", "heterogeneous", "churn"),
    )
    return out


def get_preset(name: str) -> ScenarioSpec:
    catalogue = all_presets()
    try:
        return catalogue[name]
    except KeyError:
        raise KeyError(
            f"unknown preset {name!r}; available: "
            f"{', '.join(sorted(catalogue))}"
        ) from None
