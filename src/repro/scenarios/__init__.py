"""repro.scenarios — declarative scenario suite + cached parallel sweeps.

The paper's claims are comparative (DeCaPH vs FL vs PriMIA vs local across
three multi-hospital case studies); this package makes every comparison cell
a declarative, JSON-serialisable ``ScenarioSpec``, gives the named cells a
preset library (``presets``), expands axis products with ``SweepGrid``,
executes them through a content-addressed result cache with process-pool
parallelism (``run_sweep``), and fits wall-clock/bytes scaling laws into
``BENCH_sweep.json`` + a markdown report (``report``).  See DESIGN.md §6.

    from repro.scenarios import ScenarioSpec, get_preset, get_sweep
    from repro.scenarios import ResultCache, run_sweep, run_spec

    outcome = run_sweep(get_sweep("capacity-mini").specs(), ResultCache())

CLI: ``python -m repro.scenarios --list/--run/--sweep/--report``.
"""

from repro.scenarios.cache import DEFAULT_CACHE_DIR, ResultCache
from repro.scenarios.executor import (
    SweepOutcome,
    build_scenario,
    run_spec,
    run_sweep,
)
from repro.scenarios.grid import SWEEPS, SweepGrid, get_sweep
from repro.scenarios.presets import (
    FIVE_HOSPITAL_NODES,
    FIVE_HOSPITAL_TOPOLOGY,
    FIVE_HOSPITAL_TRACE,
    all_presets,
    get_preset,
)
from repro.scenarios.report import (
    bench_payload,
    fit_power_law,
    markdown_report,
    scaling_laws,
    write_artifacts,
)
from repro.scenarios.spec import ScenarioSpec

__all__ = [
    "DEFAULT_CACHE_DIR",
    "FIVE_HOSPITAL_NODES",
    "FIVE_HOSPITAL_TOPOLOGY",
    "FIVE_HOSPITAL_TRACE",
    "ResultCache",
    "SWEEPS",
    "ScenarioSpec",
    "SweepGrid",
    "SweepOutcome",
    "all_presets",
    "bench_payload",
    "build_scenario",
    "fit_power_law",
    "get_preset",
    "get_sweep",
    "markdown_report",
    "run_spec",
    "run_sweep",
    "scaling_laws",
    "write_artifacts",
]
