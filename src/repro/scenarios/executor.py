"""Execute scenario specs: one cell, or a cached, process-parallel sweep.

``run_spec`` materialises a ``ScenarioSpec`` (cohort, model, nodes, topology,
arm config), runs it through ``repro.arms.run`` and returns a plain-JSON
metrics dict.  ``run_sweep`` drives a list of specs through the result cache:
hits are served from disk, misses execute — inline for ``jobs=1``, else on a
spawn-context process pool (JAX initialised in this process must not be
forked) — and every fresh result is persisted, making sweeps resumable.

JAX-heavy imports happen inside functions: a fully-cached sweep never
builds models, data or backends (it still pays the one arm-registry import
that sweep-axis expansion needs — see ``grid._registered_arms``).

Alongside the *result* cache sits a persistent *compilation* cache
(``<result-cache-root>/jit-cache``, DESIGN.md §7): every pool worker is a
fresh spawn-context process, and before it, each worker re-traced and
re-compiled programs every other worker (and every previous sweep) had
already built.  Wiring JAX's persistent compilation cache into the worker
initializer makes compiled programs a sweep-level artifact: cell N's
compile is cell N+1's disk hit, across processes and across invocations.
"""

from __future__ import annotations

import dataclasses
import logging
import math
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Callable, Sequence

import repro.obs as obs
from repro.scenarios import presets as presets_lib
from repro.scenarios.cache import ResultCache
from repro.scenarios.spec import ScenarioSpec

logger = logging.getLogger(__name__)

JIT_CACHE_SUBDIR = "jit-cache"


def enable_compilation_cache(cache_root: str) -> None:
    """Point JAX's persistent compilation cache under the sweep cache.

    Zero thresholds: sweep programs are many and small, and the default
    min-compile-time / min-entry-size gates would skip exactly the tiny
    programs whose per-worker recompiles dominate a parallel sweep.
    Failure is non-fatal (older jaxlibs): the sweep still runs, it just
    recompiles as before.
    """
    import os

    import jax

    path = os.path.join(str(cache_root), JIT_CACHE_SUBDIR)
    try:
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    except Exception as e:  # pragma: no cover - depends on jax version
        logger.warning("persistent compilation cache unavailable: %s", e)


def build_scenario(spec: ScenarioSpec):
    """(model, silos, cfg, nodes, topo) — everything ``repro.arms.run`` needs.

    ``nodes``/``topo`` are None for the idealized backend.
    """
    import repro.arms as arms
    from repro.arms import backends as backends_lib
    from repro.core.dp import DPConfig
    from repro.sim import Topology, nodes_from_trace

    arm_cls = arms.get(spec.arm)  # validates the arm name early
    backend_info = backends_lib.get_backend(spec.backend).info
    model = presets_lib.build_model(spec)
    silos = presets_lib.build_silos(spec)
    if presets_lib.normalizes(spec.task):
        silos = arms.normalize_participants(silos)
    cfg = arms.ArmConfig(
        rounds=spec.rounds, batch_size=spec.batch_size, lr=spec.lr,
        seed=spec.seed, use_secagg=spec.use_secagg,
        fl_local_steps=spec.fl_local_steps, fedprox_mu=spec.fedprox_mu,
        epsilon_budget=spec.epsilon_budget,
        participation_rate=spec.participation_rate,
        clipping=spec.clipping,
        dp=DPConfig(clip_norm=spec.clip_norm,
                    noise_multiplier=spec.noise_multiplier,
                    microbatch_size=spec.microbatch_size),
    )
    if not backend_info.supports_sim_time:
        return model, silos, cfg, None, None
    if spec.population is not None:
        # distributional cell: materialise the node/topology traces from the
        # population description (deterministic in spec.seed)
        from repro.population.spec import PopulationSpec

        pop = PopulationSpec.from_dict(
            {"hospitals": spec.hospitals, "seed": spec.seed,
             **spec.population}
        )
        return (model, silos, cfg, nodes_from_trace(pop.build_nodes()),
                Topology.from_trace(pop.build_topology()))
    nodes = nodes_from_trace(presets_lib.default_nodes(spec))
    if spec.topology is not None:
        topo_spec = dict(spec.topology)
        topo_spec.setdefault("n", spec.hospitals)
        topo = Topology.from_trace(topo_spec)
    else:
        kind = arm_cls.topology_kind
        spec_kind = {"kind": kind, "n": spec.hospitals,
                     "default": {"bandwidth": spec.bandwidth,
                                 "latency": spec.latency}}
        if kind == "star":
            spec_kind["center"] = cfg.fl_server
        topo = Topology.from_trace(spec_kind)
    return model, silos, cfg, nodes, topo


def run_spec(spec: ScenarioSpec) -> dict:
    """Execute one cell and return its plain-JSON metrics."""
    import jax
    import numpy as np

    import repro.arms as arms

    model, silos, cfg, nodes, topo = build_scenario(spec)
    rec = obs.recorder()
    spans_before = rec.span_totals() if rec is not None else None
    t0 = time.time()
    with obs.span("sweep.cell", cat="sweep", cell=spec.name, arm=spec.arm,
                  backend=spec.backend, hospitals=spec.hospitals):
        rep = arms.run(spec.arm, model, silos, cfg, backend=spec.backend,
                       nodes=nodes, topo=topo)
    host_seconds = time.time() - t0
    # rep.params is always the arm's headline model: node arms pick it in
    # consensus() (local -> node 0, gossip -> the average)
    headline = rep.params
    n_params = int(sum(np.prod(np.shape(leaf)) or 1
                       for leaf in jax.tree_util.tree_leaves(headline)))
    row = {
        "name": spec.name,
        "key": spec.spec_hash(),
        "task": spec.task,
        "arm": spec.arm,
        "backend": spec.backend,
        "hospitals": spec.hospitals,
        "model_size": spec.model_size,
        "model_params": n_params,
        "rounds_completed": rep.rounds_completed,
        "epsilon": float(rep.epsilon),
        # None (JSON null), not NaN: NaN breaks strict JSON consumers and
        # NaN != NaN would make cached results compare unequal to fresh ones
        "mean_loss": (float(rep.mean_loss())
                      if math.isfinite(rep.mean_loss()) else None),
        "accuracy": presets_lib.pooled_metric(spec, model, headline, silos),
        "wall_clock": float(rep.wall_clock),
        "bytes_on_wire": float(rep.bytes_on_wire),
        "dropout_events": int(rep.dropout_events),
        "recoveries": int(rep.recoveries),
        "lost_rounds": int(rep.lost_rounds),
        "events": int(rep.events),
        "noise_topups": int(rep.noise_topups),
        "host_seconds": host_seconds,
    }
    if spans_before is not None:
        # per-cell host-time phase breakdown (fused dispatch vs aggregate vs
        # transport ...) — the delta of the recorder's span totals across
        # this cell, surfaced in the BENCH row only when recording is on
        after = rec.span_totals()
        row["phase_seconds"] = {
            name: round(total - (spans_before.get(name) or (0, 0.0))[1], 6)
            for name, (_, total) in sorted(after.items())
            if total - (spans_before.get(name) or (0, 0.0))[1] > 0
            and name != "sweep.cell"
        }
    return row


def _pool_init(cache_root: str) -> None:
    """Worker initializer: persistent jit cache before any JAX import."""
    enable_compilation_cache(cache_root)


def _pool_cell(spec_dict: dict) -> dict:
    """Top-level pool target (must be picklable under spawn)."""
    return run_spec(ScenarioSpec.from_dict(spec_dict))


@dataclasses.dataclass
class SweepOutcome:
    """What a sweep invocation did: the results plus cache bookkeeping."""

    results: list[dict]
    hits: int
    misses: int
    elapsed: float

    @property
    def cells(self) -> int:
        return len(self.results)


def run_sweep(
    specs: Sequence[ScenarioSpec],
    cache: ResultCache,
    *,
    jobs: int = 1,
    force: bool = False,
    runner: Callable[[ScenarioSpec], dict] | None = None,
    progress: Callable[[str], None] | None = None,
) -> SweepOutcome:
    """Run every spec through the cache; execute only the misses.

    ``runner`` overrides cell execution (tests inject a counting fake; the
    process pool is bypassed whenever a runner is given or ``jobs <= 1``).
    """
    t0 = time.time()
    say = progress or (lambda msg: None)
    results: list[dict | None] = [None] * len(specs)
    pending: list[int] = []
    hits = 0
    for idx, spec in enumerate(specs):
        cached = None if force else cache.get(spec)
        if cached is not None:
            # relabel on serve: names are excluded from the cache key, so a
            # renamed sweep/cell must not surface its original label
            results[idx] = {**cached, "name": spec.name}
            hits += 1
        else:
            pending.append(idx)
    say(f"{len(specs)} cells: {hits} cached, {len(pending)} to run")

    if pending:
        if runner is None and jobs > 1 and len(pending) > 1:
            # spawn, not fork: this process has (or will have) a live JAX
            # runtime, whose threads do not survive forking.  Every finished
            # cell is cached as it completes, so one failing cell costs only
            # itself — the re-run resumes from everything that succeeded.
            import multiprocessing as mp
            from concurrent.futures import as_completed

            ctx = mp.get_context("spawn")
            first_error: BaseException | None = None
            with ProcessPoolExecutor(max_workers=min(jobs, len(pending)),
                                     mp_context=ctx,
                                     initializer=_pool_init,
                                     initargs=(str(cache.root),)) as pool:
                futures = {
                    pool.submit(_pool_cell, specs[i].to_dict()): i
                    for i in pending
                }
                for fut in as_completed(futures):
                    idx = futures[fut]
                    try:
                        results[idx] = fut.result()
                    except BaseException as e:  # noqa: BLE001 - re-raised
                        say(f"FAILED {specs[idx].name}: {e}")
                        first_error = first_error or e
                        continue
                    cache.put(specs[idx], results[idx])
                    say(f"ran  {specs[idx].name}")
            if first_error is not None:
                raise first_error
        else:
            if runner is None:
                # inline execution compiles in-process; same persistent cache
                enable_compilation_cache(str(cache.root))
            run_one = runner or run_spec
            for idx in pending:
                results[idx] = run_one(specs[idx])
                cache.put(specs[idx], results[idx])
                say(f"ran  {specs[idx].name}")

    return SweepOutcome(
        results=[r for r in results if r is not None],
        hits=hits, misses=len(pending), elapsed=time.time() - t0,
    )
