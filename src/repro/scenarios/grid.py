"""``SweepGrid`` — expand axis products into scenario-spec lists.

A grid is a base ``ScenarioSpec`` plus named axes (any spec field -> list of
values); ``specs()`` is the cartesian product, each cell named
``sweep/axis=value,...`` so cache entries and report rows are self-describing.

Named sweeps live in ``SWEEPS``.  The arm axis is resolved lazily from
``repro.arms.names()`` at expansion time, so a newly registered arm (e.g.
``fedprox``) joins every sweep automatically.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Mapping, Sequence

from repro.scenarios.spec import ScenarioSpec


def _registered_arms() -> tuple[str, ...]:
    # deferred: sweep expansion resolves the (jax-importing) arm registry
    import repro.arms as arms

    return arms.names()


def _registered_backends() -> tuple[str, ...]:
    """The live backend registry — a newly registered backend joins every
    backend axis automatically, exactly like arms join the arm axis."""
    from repro.arms import backends

    return backends.backend_names()


@dataclasses.dataclass
class SweepGrid:
    """Axis product over ScenarioSpec fields."""

    name: str
    base: ScenarioSpec
    axes: Mapping[str, Sequence[Any]]

    def __post_init__(self) -> None:
        fields = {f.name for f in dataclasses.fields(ScenarioSpec)}
        bad = set(self.axes) - fields
        if bad:
            raise ValueError(f"axes over unknown spec fields: {sorted(bad)}")
        for axis, values in self.axes.items():
            if not values:
                raise ValueError(f"axis {axis!r} has no values")

    def size(self) -> int:
        out = 1
        for values in self.axes.values():
            out *= len(values)
        return out

    def specs(self) -> list[ScenarioSpec]:
        keys = sorted(self.axes)
        cells = []
        for combo in itertools.product(*(self.axes[k] for k in keys)):
            assignment = dict(zip(keys, combo))
            label = ",".join(f"{k}={assignment[k]}" for k in keys)
            cells.append(self.base.replace(
                name=f"{self.name}/{label}",
                tags=self.base.tags + ("sweep:" + self.name,),
                **assignment,
            ))
        return cells


# ---------------------------------------------------------------------------
# Named sweeps (factories, so the arm axis reflects the live registry).
# ---------------------------------------------------------------------------


def _tiny_base(name_prefix: str) -> ScenarioSpec:
    """A cell that finishes in ~a second: linear model, small cohort."""
    return ScenarioSpec(
        name=name_prefix, task="gemini", model_size="small", features=8,
        examples=240, rounds=3, batch_size=32, lr=0.4, seed=0,
        backend="sim",
    )


def capacity_mini() -> SweepGrid:
    """Every registered arm x H in {3, 5}, tiny shapes — the resumable
    acceptance sweep (>= 12 cells, seconds per cell)."""
    return SweepGrid(
        "capacity-mini",
        _tiny_base("capacity-mini"),
        {"arm": list(_registered_arms()), "hospitals": [3, 5]},
    )


def capacity() -> SweepGrid:
    """The ROADMAP capacity-planning sweep: every arm x H x bandwidth tier
    x straggler ratio at medium model size (run on demand; hours of sim)."""
    base = ScenarioSpec(
        name="capacity", task="gemini", model_size="medium",
        examples=2400, rounds=12, batch_size=64, lr=0.4, backend="sim",
    )
    return SweepGrid(
        "capacity",
        base,
        {
            "arm": list(_registered_arms()),
            "hospitals": [3, 5, 10, 20],
            "bandwidth": [12.5e6, 1.25e6],       # ~100 / ~10 Mbit/s WAN
            "straggler_ratio": [0.0, 0.3],
        },
    )


def model_scaling() -> SweepGrid:
    """Every arm x model size ladder at fixed H — feeds the bytes-vs-params
    scaling law."""
    base = ScenarioSpec(
        name="model-scaling", task="gemini", model_size="small",
        hospitals=4, examples=960, rounds=4, batch_size=48, lr=0.4,
        backend="sim",
    )
    return SweepGrid(
        "model-scaling",
        base,
        {"arm": list(_registered_arms()), "model_size": ["small", "medium"]},
    )


def smoke_2x2() -> SweepGrid:
    """CI sweep: two arms x two cohort sizes, tiny models (seconds total)."""
    return SweepGrid(
        "smoke-2x2",
        _tiny_base("smoke-2x2").replace(examples=200, rounds=2),
        {"arm": ["decaph", "fedprox"], "hospitals": [3, 4]},
    )


def backend_matrix() -> SweepGrid:
    """Fused round arms x EVERY registered backend, tiny shapes.

    The backend axis is the live registry, so a new backend lands in this
    sweep (and the CI job that runs it) with zero wiring.  SecAgg is off in
    the base spec because not every backend runs the ciphertext wire
    protocol — with it on, spec validation would (correctly) reject the
    shard cells at expansion time.  The shard cells need a multi-device
    process (CI sets XLA_FLAGS=--xla_force_host_platform_device_count=8).
    """
    return SweepGrid(
        "backend-matrix",
        _tiny_base("backend-matrix").replace(
            examples=200, rounds=2, hospitals=4, use_secagg=False,
        ),
        {"arm": ["decaph", "fl"], "backend": list(_registered_backends())},
    )


def population_scaling() -> SweepGrid:
    """Cross-device scaling: fused arms x H in {50, 200, 1000} x 3 seeds on
    the population backend (k-regular overlay, 10% Poisson participation,
    5% flaky hospitals).  Extends the power-law fits to H=1000 with per-cell
    confidence intervals from the seed axis; the trace phase costs timestamp
    arithmetic only, so even the H=1000 cells run on a laptop-class host.
    """
    base = ScenarioSpec(
        name="population-scaling", task="gemini", model_size="small",
        features=16, examples=6000, rounds=5, batch_size=64, lr=0.4,
        hospitals=50,  # >= degree+1 so the base spec itself validates
        backend="population", use_secagg=False, participation_rate=0.1,
        population={
            "topology": "k_regular", "degree": 8,
            "throughput_median": 400.0, "throughput_sigma": 0.5,
            "flaky_fraction": 0.05, "mean_uptime": 120.0,
            "mean_downtime": 15.0,
        },
    )
    return SweepGrid(
        "population-scaling",
        base,
        {
            "arm": ["decaph", "fl"],
            "hospitals": [50, 200, 1000],
            "seed": [0, 1, 2],
        },
    )


def capacity_lm() -> SweepGrid:
    """The transformer capacity column (DESIGN.md §12): decaph over the
    "lm" model-size ladder, ghost vs faithful per-example clipping, on the
    idealized backend.  The wall-clock story lives in
    ``benchmarks/hotpath.py --capacity`` (BENCH_capacity.json); this sweep
    carries the utility-vs-ε side at the same cells.
    """
    base = ScenarioSpec(
        name="capacity-lm", task="lm", model_size="small",
        hospitals=4, examples=96, rounds=4, batch_size=16, lr=0.1,
        backend="ideal", use_secagg=False, microbatch_size=8,
    )
    return SweepGrid(
        "capacity-lm",
        base,
        {
            "model_size": ["small", "medium", "full"],
            "clipping": ["ghost", "per-example"],
        },
    )


SWEEPS: dict[str, Callable[[], SweepGrid]] = {
    "capacity-mini": capacity_mini,
    "capacity": capacity,
    "capacity-lm": capacity_lm,
    "model-scaling": model_scaling,
    "smoke-2x2": smoke_2x2,
    "backend-matrix": backend_matrix,
    "population-scaling": population_scaling,
}


def get_sweep(name: str) -> SweepGrid:
    try:
        return SWEEPS[name]()
    except KeyError:
        raise KeyError(
            f"unknown sweep {name!r}; available: {', '.join(sorted(SWEEPS))}"
        ) from None
