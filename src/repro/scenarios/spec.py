"""``ScenarioSpec`` — one declarative, JSON-serialisable experiment cell.

A spec pins everything a run's outcome depends on: the case-study task and
cohort shape, the federation arm and backend, the node traces (compute +
availability), the topology (including time-varying link churn via the
``schedule`` key), the DP configuration, the model preset and the seed.
``repro.scenarios.executor.run_spec`` turns a spec into metrics; the sweep
cache addresses results by ``spec_hash``.

The cache-key contract (DESIGN.md §6): the hash covers every field that can
change the run's numerics or systems metrics, and ONLY those — ``name`` and
``tags`` are labels, excluded from the hash, so renaming a cell or re-tagging
a sweep never invalidates cached results.

This module imports only the stdlib at module level, as do ``cache`` and
``report``.  Validation, however, is registry-backed (DESIGN.md §8): the
``backend`` field is checked against the live backend registry and the
(arm, backend, secagg/trace) combination is capability-negotiated, both via
a deferred import of ``repro.arms.backends`` — the same jax-paying exception
``grid._registered_arms`` already makes for the arm axis, now paid at the
first spec construction instead of the first sweep expansion.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

from repro.canon import content_hash

TASKS = ("gemini", "pancreas", "xray", "lm")
MODEL_SIZES = ("small", "medium", "full")
CLIPPING_MODES = ("auto", "ghost", "per-example")

# bump when the semantics of a field change so stale entries never alias
SPEC_SCHEMA = 3  # v3: the "lm" task + the clipping field joined the key

# label-only fields, excluded from the cache key
_UNHASHED_FIELDS = ("name", "tags")


@dataclasses.dataclass
class ScenarioSpec:
    """Everything one experiment cell depends on, JSON-serialisable."""

    name: str = ""
    task: str = "gemini"            # gemini | pancreas | xray
    arm: str = "decaph"             # any repro.arms registry name
    backend: str = "sim"            # any repro.arms.backends registry name
    hospitals: int = 5
    model_size: str = "small"       # small | medium | full
    rounds: int = 12
    batch_size: int = 64
    lr: float = 0.4
    seed: int = 0
    examples: int = 1200            # total examples across the cohort
    features: int | None = None     # None -> task/model_size default
    # privacy
    clip_norm: float = 1.0
    noise_multiplier: float = 0.8
    microbatch_size: int = 8
    epsilon_budget: float | None = None
    use_secagg: bool = True
    # per-example clipping path (DESIGN.md §12): "auto" takes the ghost path
    # exactly when the model declares the capability (dense decoder stacks)
    clipping: str = "auto"
    # arm knobs (ignored by arms that do not use them)
    fl_local_steps: int = 1
    fedprox_mu: float = 0.1
    # cross-device (population backend): Poisson cohort subsampling rate q
    participation_rate: float = 1.0
    # systems: explicit traces win over the derived defaults below
    nodes: list[dict] | None = None      # per-hospital trace dicts
    topology: dict | None = None         # Topology.from_trace dict (+schedule)
    # distributional population (PopulationSpec overrides minus hospitals/
    # seed, which this spec owns); mutually exclusive with nodes/topology
    population: dict | None = None
    # derived-trace knobs (used only when nodes/topology are None)
    bandwidth: float = 12.5e6            # bytes/s default link
    latency: float = 0.02                # seconds default link
    throughput: float = 400.0            # examples/s per hospital
    straggler_ratio: float = 0.0         # fraction of hospitals 8x slower
    tags: tuple[str, ...] = ()

    def __post_init__(self) -> None:
        self.tags = tuple(self.tags)
        self.validate()

    # -- validation ----------------------------------------------------------

    def validate(self) -> None:
        if self.task not in TASKS:
            raise ValueError(f"task {self.task!r} not in {TASKS}")
        # deferred import: registry-backed backend + capability validation
        from repro.arms import backends as backends_lib

        if not 0.0 < self.participation_rate <= 1.0:
            raise ValueError("participation_rate must be in (0, 1]")
        if self.population is not None:
            if self.nodes is not None or self.topology is not None:
                raise ValueError(
                    "population is mutually exclusive with explicit nodes/"
                    "topology traces (it *generates* them)"
                )
            owned = {"hospitals", "seed"} & set(self.population)
            if owned:
                raise ValueError(
                    f"population may not set {sorted(owned)} — the scenario "
                    f"spec's hospitals/seed fields own those"
                )
            # fail here, not mid-sweep: PopulationSpec re-validates the
            # merged dict including this spec's hospitals count
            from repro.population.spec import PopulationSpec

            PopulationSpec.from_dict(
                {"hospitals": max(self.hospitals, 2), "seed": self.seed,
                 **self.population}
            )
        backends_lib.validate_scenario(
            arm=self.arm, backend=self.backend, use_secagg=self.use_secagg,
            needs_sim_time=(self.nodes is not None
                            or self.topology is not None
                            or self.population is not None
                            or self.straggler_ratio > 0),
            participation_rate=self.participation_rate,
        )
        if self.model_size not in MODEL_SIZES:
            raise ValueError(
                f"model_size {self.model_size!r} not in {MODEL_SIZES}"
            )
        if self.clipping not in CLIPPING_MODES:
            raise ValueError(
                f"clipping {self.clipping!r} not in {CLIPPING_MODES}"
            )
        if not self.arm or not isinstance(self.arm, str):
            raise ValueError("arm must be a non-empty registry name")
        for field, lo in (("hospitals", 1), ("rounds", 1), ("batch_size", 1),
                          ("examples", 1), ("microbatch_size", 1)):
            if getattr(self, field) < lo:
                raise ValueError(f"{field} must be >= {lo}")
        for field in ("lr", "clip_norm", "noise_multiplier", "bandwidth",
                      "latency", "throughput", "straggler_ratio"):
            if getattr(self, field) < 0:
                raise ValueError(f"{field} must be >= 0")
        if not 0.0 <= self.straggler_ratio <= 1.0:
            raise ValueError("straggler_ratio must be in [0, 1]")
        if self.nodes is not None and len(self.nodes) != self.hospitals:
            raise ValueError(
                f"nodes trace has {len(self.nodes)} entries for "
                f"hospitals={self.hospitals}"
            )
        if self.features is not None and self.features < 1:
            raise ValueError("features must be >= 1")

    # -- (de)serialisation ----------------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["tags"] = list(self.tags)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ScenarioSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ScenarioSpec fields: {sorted(unknown)}")
        return cls(**dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ScenarioSpec":
        return cls.from_dict(json.loads(s))

    def replace(self, **changes: Any) -> "ScenarioSpec":
        return dataclasses.replace(self, **changes)

    # -- cache key -------------------------------------------------------------

    def hash_material(self) -> dict[str, Any]:
        """The exact dict the cache key is computed over (DESIGN.md §6)."""
        d = self.to_dict()
        for field in _UNHASHED_FIELDS:
            d.pop(field)
        d["_schema"] = SPEC_SCHEMA
        return d

    def spec_hash(self) -> str:
        return content_hash(self.hash_material(), chars=20)
