"""Content-addressed on-disk cache for sweep cell results.

One JSON file per cell, named by ``ScenarioSpec.spec_hash()``.  Re-running a
sweep therefore only executes new/changed cells — a sweep interrupted at
cell 40/112 resumes where it left off, and editing one axis value only
invalidates the cells it touches.

Robustness contract (tested in ``tests/test_scenarios.py``): a corrupted or
stale entry (unparseable JSON, schema mismatch, key/spec mismatch, missing
result fields) is treated as a miss — logged loudly, evicted, recomputed —
never an exception and never silently wrong data.
"""

from __future__ import annotations

import json
import logging
import os
from pathlib import Path

from repro.scenarios.spec import ScenarioSpec

logger = logging.getLogger(__name__)

CACHE_SCHEMA = 1
DEFAULT_CACHE_DIR = ".sweep_cache"

# every field the report layer dereferences must be present, or the entry
# is treated as corrupted — served entries must never crash reporting
_REQUIRED_RESULT_KEYS = frozenset(
    {"name", "arm", "backend", "hospitals", "model_size", "model_params",
     "rounds_completed", "epsilon", "accuracy", "wall_clock",
     "bytes_on_wire", "recoveries"}
)


class ResultCache:
    """Spec-hash-addressed store of cell results."""

    def __init__(self, root: str | os.PathLike = DEFAULT_CACHE_DIR) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def path(self, spec: ScenarioSpec) -> Path:
        return self.root / f"{spec.spec_hash()}.json"

    def get(self, spec: ScenarioSpec) -> dict | None:
        """The cached result for ``spec``, or None (miss / evicted)."""
        path = self.path(spec)
        if not path.exists():
            return None
        try:
            entry = json.loads(path.read_text())
            if entry["schema"] != CACHE_SCHEMA:
                raise ValueError(f"schema {entry['schema']} != {CACHE_SCHEMA}")
            if entry["key"] != spec.spec_hash():
                raise ValueError("key does not match spec hash")
            result = entry["result"]
            missing = _REQUIRED_RESULT_KEYS - set(result)
            if missing:
                raise ValueError(f"result missing fields {sorted(missing)}")
        except (OSError, ValueError, KeyError, TypeError) as e:
            logger.warning(
                "corrupted cache entry %s for %s (%s); evicting and "
                "recomputing", path, spec.name or spec.spec_hash(), e,
            )
            try:
                path.unlink()
            except OSError:
                pass
            return None
        return result

    def put(self, spec: ScenarioSpec, result: dict) -> Path:
        """Atomically persist ``result`` under ``spec``'s hash."""
        path = self.path(spec)
        entry = {
            "schema": CACHE_SCHEMA,
            "key": spec.spec_hash(),
            "spec": spec.to_dict(),
            "result": result,
        }
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps(entry, indent=2, sort_keys=True))
        os.replace(tmp, path)
        return path

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("*.json"))
