"""Scaling-law analysis over sweep cells + report/artifact emission.

Fits log-log least-squares power laws per arm from the sweep's cells:

  * simulated wall-clock vs cohort size H   (``wall ∝ H^b``)
  * bytes-on-wire vs cohort size H
  * bytes-on-wire vs model parameter count  (when the sweep varies size)

and renders a markdown report (scaling-law tables + the raw cell table)
plus the ``BENCH_sweep.json`` artifact CI uploads — the repo's perf
trajectory for the ROADMAP's capacity-planning item.

Pure stdlib: fitting two-point-or-more lines in log space needs no numpy,
and the report path must stay importable without the JAX stack.
"""

from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Sequence


def fit_power_law(xs: Sequence[float], ys: Sequence[float]) -> dict | None:
    """Least-squares fit of ``y = a * x^b`` in log-log space.

    Points with a non-positive x or y are dropped (logs undefined — e.g. a
    zero-traffic arm).  Returns {"exponent", "coefficient", "r2", "points"}
    over the surviving points, or None when fewer than two distinct x
    values survive.
    """
    pts = [(x, y) for x, y in zip(xs, ys) if x > 0 and y > 0]
    if len({x for x, _ in pts}) < 2:
        return None
    lx = [math.log(x) for x, _ in pts]
    ly = [math.log(y) for _, y in pts]
    n = len(pts)
    mx, my = sum(lx) / n, sum(ly) / n
    var = sum((x - mx) ** 2 for x in lx)
    b = sum((x - mx) * (y - my) for x, y in zip(lx, ly)) / var
    a = my - b * mx
    ss_res = sum((y - (a + b * x)) ** 2 for x, y in zip(lx, ly))
    ss_tot = sum((y - my) ** 2 for y in ly)
    r2 = 1.0 if ss_tot == 0 else 1.0 - ss_res / ss_tot
    return {"exponent": b, "coefficient": math.exp(a), "r2": r2, "points": n}


# Metrics averaged (with a CI) across a multi-seed axis; everything else in
# a seed group must agree or the group is not a seed group.
_SEED_METRICS = ("epsilon", "accuracy", "mean_loss", "wall_clock",
                 "bytes_on_wire", "rounds_completed", "recoveries",
                 "lost_rounds", "dropout_events", "noise_topups",
                 "host_seconds")
_GROUP_KEYS = ("task", "arm", "backend", "hospitals", "model_size",
               "model_params")


def aggregate_seeds(cells: Sequence[dict]) -> list[dict]:
    """Collapse a sweep's seed axis: one row per (task, arm, backend, H,
    model size), metrics averaged with a 95% normal CI half-width
    (``<metric>_ci`` = 1.96 * sd / sqrt(n); omitted for singleton groups).

    Cells missing a group key (foreign payloads) pass through untouched.
    Output rows carry ``seeds`` (the group size); power-law fits run over
    these group means, which for singleton groups reproduces the ungrouped
    fit exactly.
    """
    groups: dict[tuple, list[dict]] = {}
    passthrough: list[dict] = []
    for c in cells:
        if any(k not in c for k in _GROUP_KEYS):
            passthrough.append(dict(c))
            continue
        groups.setdefault(tuple(c[k] for k in _GROUP_KEYS), []).append(c)
    out: list[dict] = []
    for key, rows in groups.items():
        row = dict(rows[0])
        row["seeds"] = len(rows)
        if len(rows) > 1:
            # strip the seed-specific label; the group keys identify the row
            row["name"] = "{}/{}".format(
                rows[0].get("name", "").split("/")[0] or rows[0]["arm"],
                ",".join(f"{k}={v}" for k, v in zip(_GROUP_KEYS, key)
                         if k in ("arm", "hospitals", "model_size")),
            )
            for m in _SEED_METRICS:
                vals = [r[m] for r in rows
                        if isinstance(r.get(m), (int, float))]
                if len(vals) != len(rows):
                    continue  # a None (NaN mean_loss) voids the average
                n = len(vals)
                mean = sum(vals) / n
                sd = math.sqrt(sum((v - mean) ** 2 for v in vals) / (n - 1))
                row[m] = mean
                row[m + "_ci"] = 1.96 * sd / math.sqrt(n)
        out.append(row)
    out.extend(passthrough)
    return out


def _fit_by_arm(cells: list[dict], x_key: str, y_key: str) -> dict[str, dict]:
    arms = sorted({c["arm"] for c in cells})
    out = {}
    for arm in arms:
        rows = [c for c in cells if c["arm"] == arm]
        fit = fit_power_law([c[x_key] for c in rows],
                            [c[y_key] for c in rows])
        if fit is not None:
            out[arm] = fit
    return out


def scaling_laws(cells: Sequence[dict]) -> dict:
    """All fits the sweep's cells support, keyed by law name.

    Systems laws fit over cells that carried a simulated-time story (any
    backend whose runs advanced a simulated clock — zero-traffic arms like
    ``local`` still count), not a hardcoded backend name.  The seed axis is
    collapsed first (``aggregate_seeds``): fits run over per-group means so
    a sweep with 3 seeds per cell contributes one point per cell, not three
    coincident ones that would overweight replicated configurations.
    """
    sim = [c for c in aggregate_seeds(cells) if c.get("wall_clock", 0) > 0]
    return {
        "wall_clock_vs_hospitals": _fit_by_arm(sim, "hospitals", "wall_clock"),
        "bytes_vs_hospitals": _fit_by_arm(sim, "hospitals", "bytes_on_wire"),
        "bytes_vs_model_params": _fit_by_arm(sim, "model_params",
                                             "bytes_on_wire"),
    }


_LAW_TITLES = {
    "wall_clock_vs_hospitals": ("Simulated wall-clock vs cohort size",
                                "wall ∝ H^b"),
    "bytes_vs_hospitals": ("Bytes on wire vs cohort size", "bytes ∝ H^b"),
    "bytes_vs_model_params": ("Bytes on wire vs model size",
                              "bytes ∝ params^b"),
}


def markdown_report(sweep_name: str, cells: Sequence[dict],
                    laws: dict | None = None) -> str:
    """The human-readable sweep report (scaling laws + cell table)."""
    laws = laws if laws is not None else scaling_laws(cells)
    lines = [f"# Sweep `{sweep_name}` — {len(cells)} cells", ""]
    for law, fits in laws.items():
        title, form = _LAW_TITLES.get(law, (law, "y ∝ x^b"))
        if not fits:
            continue
        lines += [f"## {title} ({form})", "",
                  "| arm | exponent b | coefficient a | R² | cells |",
                  "|---|---|---|---|---|"]
        for arm, fit in sorted(fits.items()):
            lines.append(
                f"| {arm} | {fit['exponent']:.3f} | "
                f"{fit['coefficient']:.4g} | {fit['r2']:.3f} | "
                f"{fit['points']} |"
            )
        lines.append("")
    grouped = [g for g in aggregate_seeds(cells) if g.get("seeds", 1) > 1]
    if grouped:
        lines += ["## Seed groups (mean ± 95% CI)", "",
                  "| group | arm | H | seeds | ε | utility | "
                  "sim wall (s) | bytes |",
                  "|---|---|---|---|---|---|---|---|"]

        def pm(g: dict, m: str, fmt: str) -> str:
            ci = g.get(m + "_ci")
            base = format(g[m], fmt)
            return base if ci is None else f"{base} ± {format(ci, fmt)}"

        for g in grouped:
            lines.append(
                f"| {g['name']} | {g['arm']} | {g['hospitals']} | "
                f"{g['seeds']} | {pm(g, 'epsilon', '.2f')} | "
                f"{pm(g, 'accuracy', '.3f')} | "
                f"{pm(g, 'wall_clock', '.3f')} | "
                f"{pm(g, 'bytes_on_wire', '.3g')} |"
            )
        lines.append("")
    lines += ["## Cells", "",
              "| cell | arm | H | size | rounds | ε | utility | "
              "sim wall (s) | host (s) | bytes | recov | topups |",
              "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        # sim wall vs host seconds side by side: the simulated federation
        # clock tells the systems story, host seconds what the sweep paid;
        # .get() keeps pre-topup cached cells renderable
        host = c.get("host_seconds")
        lines.append(
            f"| {c['name']} | {c['arm']} | {c['hospitals']} | "
            f"{c['model_size']} | {c['rounds_completed']} | "
            f"{c['epsilon']:.2f} | {c['accuracy']:.3f} | "
            f"{c['wall_clock']:.3f} | "
            f"{'-' if host is None else format(host, '.3f')} | "
            f"{c['bytes_on_wire']:.0f} | {c['recoveries']} | "
            f"{c.get('noise_topups', '-')} |"
        )
    lines.append("")
    return "\n".join(lines)


def bench_payload(sweep_name: str, cells: Sequence[dict],
                  laws: dict | None = None) -> dict:
    """The ``BENCH_sweep.json`` structure (CI artifact)."""
    return {
        "sweep": sweep_name,
        "cells": list(cells),
        "seed_groups": aggregate_seeds(cells),
        "scaling_laws": laws if laws is not None else scaling_laws(cells),
        "generated_by": "python -m repro.scenarios",
    }


def write_artifacts(sweep_name: str, cells: Sequence[dict],
                    out_json: str | Path) -> tuple[Path, Path]:
    """Write BENCH_sweep.json + the sibling .md; returns both paths."""
    laws = scaling_laws(cells)
    out_json = Path(out_json)
    out_json.write_text(
        json.dumps(bench_payload(sweep_name, cells, laws), indent=2,
                   sort_keys=True)
    )
    out_md = out_json.with_suffix(".md")
    out_md.write_text(markdown_report(sweep_name, cells, laws))
    return out_json, out_md
