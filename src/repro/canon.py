"""THE canonical-JSON content-hash discipline, in one place.

Every content-addressed artifact in the repo — ``population.graph`` node
ids and graph hashes (DESIGN.md §10), ``obs.ledger`` entry ids (§11),
``scenarios.spec`` cache keys (§6) — hashes the SAME byte encoding:
``json.dumps(obj, sort_keys=True, separators=(",", ":"))`` through
sha256.  Any site that spells its own ``json.dumps`` + ``hashlib``
combination can silently diverge (a stray ``indent=``, default
separators, unsorted keys) and fork the address space, so the encoding
lives here and the ``canonical-hash-discipline`` rule in
``repro.analysis`` (DESIGN.md §13) flags every hand-rolled copy.

Stdlib-only: the trace phase and the obs core must never pay the JAX
import.
"""

from __future__ import annotations

import hashlib
import json
from typing import Any

__all__ = ["canonical_json_bytes", "content_hash", "bytes_hash"]


def canonical_json_bytes(obj: Any) -> bytes:
    """The one canonical byte encoding content hashes are computed over."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


def bytes_hash(raw: bytes, *, chars: int = 16) -> str:
    """sha256 hex digest of ``raw``, truncated to ``chars`` characters."""
    return hashlib.sha256(raw).hexdigest()[:chars]


def content_hash(obj: Any, *, chars: int = 16) -> str:
    """sha256 of the canonical JSON encoding of ``obj`` (first ``chars``)."""
    return bytes_hash(canonical_json_bytes(obj), chars=chars)
